"""Decode hot-path microbenchmark: steps/s, host overhead, donation proof.

Validates the zero-copy decode hot path three ways:

* **steps/s, tokens/s** — full ``decode_step`` iterations at a fixed batch.
* **host overhead per step** — wall time of ``decode_step`` minus wall time
  of the raw jitted step with pre-built arguments: the cost of the engine's
  Python bookkeeping (table building, token rings, stats) per iteration.
* **buffer inspection** — lowers the jitted decode step and the prefill
  scatter and asserts, from the StableHLO/optimized-HLO text, that
  ``k_pool``/``v_pool`` are donated (``tf.aliasing_output``) and that no
  full-pool-shaped ``copy`` instruction survives on either path.

Usage: PYTHONPATH=src python -m benchmarks.run --only decode_hotpath [--quick]
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.request import Kind, Request
from repro.engine.engine import ServingEngine
from repro.engine import kv_cache
from repro.models.model import build_model


def lower_decode_step(eng: ServingEngine, *, bucket: int = 8, pages: int = 8):
    """Lower the engine's jitted decode step for shape-only inspection."""
    fn = eng._decode_fn(bucket, pages)
    zi = jnp.zeros((bucket,), jnp.int32)
    return fn.lower(
        eng.params, zi, zi, jnp.zeros((bucket, pages), jnp.int32),
        jnp.ones((bucket,), jnp.int32), eng.cache.k_pool, eng.cache.v_pool,
        jax.random.PRNGKey(0), jnp.int32(0),
        jnp.zeros((bucket,), jnp.float32), jnp.zeros((bucket,), jnp.int32))


def lower_prefill_scatter(eng: ServingEngine, *, n_layers: int | None = None,
                          S: int = 16):
    """Lower the donated prefill KV scatter for shape-only inspection."""
    cfg = eng.cfg
    n = n_layers or cfg.num_layers
    kv = jnp.zeros((n, S, cfg.num_kv_heads, cfg.head_dim_),
                   eng.cache.k_pool.dtype)
    idx = jnp.zeros((S,), jnp.int32)
    return kv_cache._scatter_layers.lower(
        eng.cache.k_pool, eng.cache.v_pool, jnp.zeros((n,), jnp.int32),
        idx, idx, kv, kv)


def donation_report(lowered, pool_shape) -> dict:
    """Count donated (aliased) args and surviving full-pool copies."""
    donated = lowered.as_text().count("tf.aliasing_output")
    dims = ",".join(map(str, pool_shape))
    hlo = lowered.compile().as_text()
    copies = sum(1 for line in hlo.splitlines()
                 if "copy(" in line and f"[{dims}]" in line)
    return {"donated_args": donated, "full_pool_copies": copies}


def run_decode_hotpath(arch="qwen2.5-7b", batch=8, prompt_len=64, steps=30,
                       backend="auto", seed=0, verbose=True):
    cfg = get_config(arch).reduced(layers=4, d_model=512, vocab=4096, d_ff=1536)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(seed))
    eng = ServingEngine(model, params, num_pages=1024, page_size=16,
                        decode_buckets=(batch,), backend=backend)
    rng = np.random.RandomState(seed)
    reqs = []
    for _ in range(batch):
        prompt = list(rng.randint(0, cfg.vocab_size, prompt_len))
        r = Request(Kind.OFFLINE, 0.0, prompt_len, 10 ** 6)  # never finishes
        eng.add_request(r, prompt)
        eng.prefill(r.rid)
        reqs.append(r)
    rids = [r.rid for r in reqs]
    eng.decode_step(rids)  # compile + warm

    # --- full decode_step (engine bookkeeping included) -------------------
    t0 = time.perf_counter()
    for _ in range(steps):
        eng.decode_step(rids)
    full_dt = (time.perf_counter() - t0) / steps

    # --- raw jitted step with pre-built args (device + dispatch only) -----
    bucket = eng._bucket(batch)
    pages = eng.pad_pages(max(len(eng.cache.tables[r]) for r in rids))
    fn = eng._decode_fn(bucket, pages)
    tables = jnp.asarray(eng.cache.batch_tables(rids, pad_to=pages))
    positions = jnp.asarray(
        np.array([eng.requests[r].context_len - 1 for r in rids], np.int32))
    tokens = jnp.asarray(np.array([eng.token_buf[r][-1] for r in rids], np.int32))
    lengths = positions + 1
    temps = jnp.zeros((bucket,), jnp.float32)
    topks = jnp.zeros((bucket,), jnp.int32)
    key = jax.random.PRNGKey(0)
    nxt, eng.cache.k_pool, eng.cache.v_pool = fn(
        eng.params, tokens, positions, tables, lengths,
        eng.cache.k_pool, eng.cache.v_pool, key, jnp.int32(0), temps, topks)
    nxt.block_until_ready()
    t0 = time.perf_counter()
    for i in range(steps):
        nxt, eng.cache.k_pool, eng.cache.v_pool = fn(
            eng.params, tokens, positions, tables, lengths,
            eng.cache.k_pool, eng.cache.v_pool, key, jnp.int32(i), temps, topks)
    nxt.block_until_ready()
    raw_dt = (time.perf_counter() - t0) / steps

    pool_shape = eng.cache.k_pool.shape
    dec = donation_report(lower_decode_step(eng, bucket=bucket, pages=pages),
                          pool_shape)
    pre = donation_report(lower_prefill_scatter(eng), pool_shape)

    out = {
        "backend": eng.backend,
        "batch": batch,
        "steps_per_s": 1.0 / full_dt,
        "tokens_per_s": batch / full_dt,
        "host_overhead_ms_per_step": max(full_dt - raw_dt, 0.0) * 1e3,
        "decode_donated_args": dec["donated_args"],
        "decode_full_pool_copies": dec["full_pool_copies"],
        "prefill_donated_args": pre["donated_args"],
        "prefill_full_pool_copies": pre["full_pool_copies"],
    }
    if verbose:
        print(f"  decode hot path ({eng.backend}, B={batch}): "
              f"{out['steps_per_s']:.1f} steps/s, "
              f"{out['tokens_per_s']:.0f} tok/s, "
              f"host overhead {out['host_overhead_ms_per_step']:.2f} ms/step")
        print(f"  donation: decode {dec['donated_args']} aliased args / "
              f"{dec['full_pool_copies']} full-pool copies; prefill scatter "
              f"{pre['donated_args']} aliased / {pre['full_pool_copies']} copies")
    return out
