"""Decode hot-path microbenchmark: steps/s, host overhead, donation proof,
multi-step decode-horizon amortization.

Validates the zero-copy decode hot path four ways:

* **steps/s, tokens/s** — full ``decode_step`` iterations at a fixed batch.
* **host overhead per step** — wall time of ``decode_step`` minus wall time
  of the raw jitted step with pre-built arguments: the cost of the engine's
  Python bookkeeping (table building, token rings, stats) per iteration,
  i.e. the time between one step's device->host sync and the next dispatch.
  Reported both absolute and as a **fraction of the dispatch** — the
  quantity multi-step horizons amortize.
* **buffer inspection** — lowers the jitted decode step, the prefill
  scatter, and the K-step horizon scan and asserts, from the
  StableHLO/optimized-HLO text, that ``k_pool``/``v_pool`` are donated
  (``tf.aliasing_output``) and that no full-pool-shaped ``copy``
  instruction survives on any path.
* **horizon amortization** (``run_horizon_amortization``) — tokens/s of
  ``decode_horizon`` at K in {1, 4, 16} on a small latency-bound batch
  (identical decode math; K=1 is today's one-sync-per-token behavior),
  plus the roofline-suggested K (``PerfModel.suggest_decode_horizon`` fed
  the measured per-dispatch overhead). The K=16-vs-K=1 ratio is the
  regression gate recorded in ``BENCH_engine.json``.
* **mixed-horizon amortization** (``run_mixed_horizon_amortization``) —
  tokens/s of the fused mixed-horizon dispatch (K decode iterations + K
  prefill sub-chunk slices, one host sync) vs K serial ``mixed_step``
  calls at identical per-iteration work, with bit-exact greedy parity
  asserted across every K, one-sync-per-dispatch counted from EngineStats,
  and the donation proof of the fused scan. ``fused_speedup`` (K=16 vs
  serial) is the second regression gate in ``BENCH_engine.json``.

Usage: PYTHONPATH=src python -m benchmarks.run --only decode_hotpath [--quick]
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.request import Kind, Request
from repro.engine.engine import ServingEngine
from repro.engine import kv_cache
from repro.models.model import build_model


def lower_decode_step(eng: ServingEngine, *, bucket: int = 8, pages: int = 8):
    """Lower the engine's jitted decode step for shape-only inspection."""
    fn = eng._decode_fn(bucket, pages)
    zi = jnp.zeros((bucket,), jnp.int32)
    return fn.lower(
        eng.params, zi, zi, jnp.zeros((bucket, pages), jnp.int32),
        jnp.ones((bucket,), jnp.int32), eng.cache.k_pool, eng.cache.v_pool,
        jax.random.PRNGKey(0), jnp.int32(0),
        jnp.zeros((bucket,), jnp.float32), jnp.zeros((bucket,), jnp.int32))


def lower_prefill_scatter(eng: ServingEngine, *, n_layers: int | None = None,
                          S: int = 16):
    """Lower the donated prefill KV scatter for shape-only inspection."""
    cfg = eng.cfg
    n = n_layers or cfg.num_layers
    kv = jnp.zeros((n, S, cfg.num_kv_heads, cfg.head_dim_),
                   eng.cache.k_pool.dtype)
    idx = jnp.zeros((S,), jnp.int32)
    return kv_cache._scatter_layers.lower(
        eng.cache.k_pool, eng.cache.v_pool, jnp.zeros((n,), jnp.int32),
        idx, idx, kv, kv)


def lower_horizon_step(eng: ServingEngine, *, bucket: int = 8, pages: int = 8,
                       steps: int = 4):
    """Lower the jitted K-step horizon scan for shape-only inspection."""
    fn = eng._horizon_fn(bucket, pages, steps)
    zi = jnp.zeros((bucket,), jnp.int32)
    return fn.lower(
        eng.params, zi, zi, jnp.zeros((bucket, pages), jnp.int32),
        eng.cache.k_pool, eng.cache.v_pool, jnp.ones((bucket,), jnp.int32),
        jax.random.PRNGKey(0), jnp.int32(1),
        jnp.zeros((bucket,), jnp.float32), jnp.zeros((bucket,), jnp.int32))


def lower_mixed_horizon_step(eng: ServingEngine, *, bucket: int = 2,
                             pages: int = 8, chunk_bucket: int = 8,
                             chunk_pages: int = 8, steps: int = 4):
    """Lower the jitted K-step fused mixed-horizon scan for shape-only
    inspection."""
    fn = eng._mixed_horizon_fn(bucket, pages, chunk_bucket, chunk_pages,
                               steps)
    zi = jnp.zeros((bucket,), jnp.int32)
    return fn.lower(
        eng.params, zi, zi, jnp.zeros((bucket, pages), jnp.int32),
        eng.cache.k_pool, eng.cache.v_pool, jnp.ones((bucket,), jnp.int32),
        jnp.zeros((steps, chunk_bucket), jnp.int32),
        jnp.zeros((steps, 2), jnp.int32),
        jnp.zeros((chunk_pages,), jnp.int32),
        jax.random.PRNGKey(0), jnp.int32(1),
        jnp.zeros((bucket + 1,), jnp.float32),
        jnp.zeros((bucket + 1,), jnp.int32))


def donation_report(lowered, pool_shape) -> dict:
    """Count donated (aliased) args and surviving full-pool copies."""
    donated = lowered.as_text().count("tf.aliasing_output")
    dims = ",".join(map(str, pool_shape))
    hlo = lowered.compile().as_text()
    copies = sum(1 for line in hlo.splitlines()
                 if "copy(" in line and f"[{dims}]" in line)
    return {"donated_args": donated, "full_pool_copies": copies}


def run_decode_hotpath(arch="qwen2.5-7b", batch=8, prompt_len=64, steps=30,
                       backend="auto", seed=0, verbose=True):
    cfg = get_config(arch).reduced(layers=4, d_model=512, vocab=4096, d_ff=1536)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(seed))
    eng = ServingEngine(model, params, num_pages=1024, page_size=16,
                        decode_buckets=(batch,), backend=backend)
    rng = np.random.RandomState(seed)
    reqs = []
    for _ in range(batch):
        prompt = list(rng.randint(0, cfg.vocab_size, prompt_len))
        r = Request(Kind.OFFLINE, 0.0, prompt_len, 10 ** 6)  # never finishes
        eng.add_request(r, prompt)
        eng.prefill(r.rid)
        reqs.append(r)
    rids = [r.rid for r in reqs]
    eng.decode_step(rids)  # compile + warm

    # --- full decode_step (engine bookkeeping included) -------------------
    t0 = time.perf_counter()
    for _ in range(steps):
        eng.decode_step(rids)
    full_dt = (time.perf_counter() - t0) / steps

    # --- raw jitted step with pre-built args (device + dispatch only) -----
    bucket = eng._bucket(batch)
    pages = eng.pad_pages(max(len(eng.cache.tables[r]) for r in rids))
    fn = eng._decode_fn(bucket, pages)
    tables = jnp.asarray(eng.cache.batch_tables(rids, pad_to=pages))
    positions = jnp.asarray(
        np.array([eng.requests[r].context_len - 1 for r in rids], np.int32))
    tokens = jnp.asarray(np.array([eng.token_buf[r][-1] for r in rids], np.int32))
    lengths = positions + 1
    temps = jnp.zeros((bucket,), jnp.float32)
    topks = jnp.zeros((bucket,), jnp.int32)
    key = jax.random.PRNGKey(0)
    nxt, eng.cache.k_pool, eng.cache.v_pool = fn(
        eng.params, tokens, positions, tables, lengths,
        eng.cache.k_pool, eng.cache.v_pool, key, jnp.int32(0), temps, topks)
    nxt.block_until_ready()
    t0 = time.perf_counter()
    for i in range(steps):
        nxt, eng.cache.k_pool, eng.cache.v_pool = fn(
            eng.params, tokens, positions, tables, lengths,
            eng.cache.k_pool, eng.cache.v_pool, key, jnp.int32(i), temps, topks)
    nxt.block_until_ready()
    raw_dt = (time.perf_counter() - t0) / steps

    pool_shape = eng.cache.k_pool.shape
    dec = donation_report(lower_decode_step(eng, bucket=bucket, pages=pages),
                          pool_shape)
    pre = donation_report(lower_prefill_scatter(eng), pool_shape)

    out = {
        "backend": eng.backend,
        "batch": batch,
        "steps_per_s": 1.0 / full_dt,
        "tokens_per_s": batch / full_dt,
        "host_overhead_ms_per_step": max(full_dt - raw_dt, 0.0) * 1e3,
        # fraction of each dispatch spent host-side between the sync and
        # the next dispatch — what a K-step horizon divides by K
        "host_overhead_fraction": max(full_dt - raw_dt, 0.0) / full_dt,
        "decode_donated_args": dec["donated_args"],
        "decode_full_pool_copies": dec["full_pool_copies"],
        "prefill_donated_args": pre["donated_args"],
        "prefill_full_pool_copies": pre["full_pool_copies"],
    }
    if verbose:
        print(f"  decode hot path ({eng.backend}, B={batch}): "
              f"{out['steps_per_s']:.1f} steps/s, "
              f"{out['tokens_per_s']:.0f} tok/s, "
              f"host overhead {out['host_overhead_ms_per_step']:.2f} ms/step "
              f"({out['host_overhead_fraction']:.1%} of dispatch)")
        print(f"  donation: decode {dec['donated_args']} aliased args / "
              f"{dec['full_pool_copies']} full-pool copies; prefill scatter "
              f"{pre['donated_args']} aliased / {pre['full_pool_copies']} copies")
    return out


def run_horizon_amortization(arch="qwen2.5-7b", batch=2, prompt_len=32,
                             ks=(1, 4, 16), total_steps=64, backend="auto",
                             seed=0, verbose=True):
    """Multi-step decode-horizon amortization on a small latency-bound
    batch: tokens/s at each K (identical per-step math — K=1 runs today's
    ``decode_step`` loop with one host sync per token, K>1 runs
    ``decode_horizon`` with one sync per K tokens), the measured
    per-dispatch host overhead, the roofline-suggested K, and the donation
    proof of the horizon scan from the lowered HLO."""
    from repro.core.hardware import cpu_measured
    from repro.core.perf_model import PerfModel

    assert 1 in ks, "amortization is measured against K=1 (today's behavior)"
    cfg = get_config(arch).reduced(layers=4, d_model=512, vocab=4096, d_ff=1536)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(seed))
    eng = ServingEngine(model, params, num_pages=2048, page_size=16,
                        decode_buckets=(batch,), backend=backend)
    rng = np.random.RandomState(seed)
    tok_per_s: dict[int, float] = {}
    for K in ks:
        # fresh residents per K so every variant decodes from the same
        # context state (free the previous set's pages first)
        for rid in list(eng.requests):
            eng.cache.free(rid)
        eng.requests.clear()
        eng.token_buf.clear()
        rids = []
        for _ in range(batch):
            prompt = list(rng.randint(0, cfg.vocab_size, prompt_len))
            r = Request(Kind.OFFLINE, 0.0, prompt_len, 10 ** 6)
            eng.add_request(r, prompt)
            eng.prefill(r.rid)
            rids.append(r.rid)
        # warm/compile the variant, advancing EVERY variant by the same
        # max(ks) steps so the timed windows cover identical context ranges
        n = 0
        while n < max(ks):
            if K == 1:
                eng.decode_step(rids)
                n += 1
            else:
                eng.decode_horizon(rids, K)
                n += K
        n = 0
        t0 = time.perf_counter()
        while n < total_steps:
            if K == 1:
                eng.decode_step(rids)
                n += 1
            else:
                eng.decode_horizon(rids, K)
                n += K
        dt = time.perf_counter() - t0
        tok_per_s[K] = batch * n / dt
    base = run_decode_hotpath(arch=arch, batch=batch, prompt_len=prompt_len,
                              steps=max(total_steps // 4, 8), backend=backend,
                              seed=seed, verbose=False)
    # implied per-dispatch overhead from the K-scaling itself: modeling a
    # step as work + overhead/K, the K=1 vs K=max pair solves for the full
    # dispatch cost (arg build + jit call + device->host sync) — the
    # raw-loop measurement in run_decode_hotpath only sees the Python
    # bookkeeping slice of it, since the raw loop still dispatches per step
    lo, hi = min(ks), max(ks)
    t_lo, t_hi = batch / tok_per_s[lo], batch / tok_per_s[hi]
    implied_ov = max((t_lo - t_hi) / (1.0 / lo - 1.0 / hi), 0.0)
    work = max(t_lo - implied_ov / lo, 1e-9)
    pm = PerfModel(cfg, cpu_measured())
    ctx = [prompt_len + total_steps // 2] * batch
    suggested = pm.suggest_decode_horizon(
        ctx, dispatch_overhead=max(implied_ov,
                                   base["host_overhead_ms_per_step"] * 1e-3),
        max_horizon=max(ks))
    chosen = min(ks, key=lambda k: abs(k - suggested))  # nearest measured K
    hz = donation_report(lower_horizon_step(eng, bucket=batch,
                                            pages=eng.pad_pages(
                                                eng.cache.pages_for(
                                                    prompt_len + total_steps)),
                                            steps=4),
                         eng.cache.k_pool.shape)
    out = {
        "backend": eng.backend,
        "batch": batch,
        "tokens_per_s_by_k": {str(k): tok_per_s[k] for k in ks},
        "bookkeeping_ms_per_dispatch": base["host_overhead_ms_per_step"],
        "implied_dispatch_overhead_ms": implied_ov * 1e3,
        "dispatch_overhead_fraction": implied_ov / (implied_ov + work),
        "suggested_k": suggested,
        "chosen_k": chosen,
        "chosen_speedup": tok_per_s[chosen] / tok_per_s[1],
        "k16_speedup": (tok_per_s[16] / tok_per_s[1]
                        if 16 in tok_per_s else None),
        "horizon_donated_args": hz["donated_args"],
        "horizon_full_pool_copies": hz["full_pool_copies"],
    }
    if verbose:
        by_k = " ".join(f"K={k}:{v:.1f}" for k, v in tok_per_s.items())
        k16 = (f" (K=16: {out['k16_speedup']:.2f}x)"
               if out["k16_speedup"] is not None else "")
        print(f"  decode horizon ({eng.backend}, B={batch}): {by_k} tok/s; "
              f"dispatch overhead {out['implied_dispatch_overhead_ms']:.1f} ms "
              f"({out['dispatch_overhead_fraction']:.0%} of a K=1 step); "
              f"suggested K={suggested} -> {out['chosen_speedup']:.2f}x vs K=1"
              f"{k16}; horizon donation "
              f"{hz['donated_args']} aliased / {hz['full_pool_copies']} copies")
    return out


def run_mixed_horizon_amortization(arch="qwen2.5-7b", batch=2, prompt_len=32,
                                   sub_tokens=8, ks=(1, 4, 16),
                                   total_steps=64, backend="auto", seed=0,
                                   verbose=True):
    """Fused mixed-horizon amortization: a decode batch rides K iterations
    in ONE dispatch while a long offline prefill lands as K fixed-size
    sub-chunk slices of the same dispatch.  K=1 is today's serial
    ``mixed_step`` (one host sync per sub-chunk); K>1 is
    ``mixed_horizon`` (one sync per K).  The per-iteration work is held
    constant — every variant lands ``sub_tokens`` prompt tokens and one
    decode token per resident per iteration — so the K=16-vs-K=1 ratio
    isolates dispatch+sync amortization.  Greedy token streams are
    asserted bit-identical across every K (the engine's parity contract)
    and host syncs are counted: exactly one per dispatch."""
    from repro.core.hardware import cpu_measured
    from repro.core.perf_model import PerfModel

    assert 1 in ks, "amortization is measured against K=1 (serial mixed_step)"
    cfg = get_config(arch).reduced(layers=4, d_model=512, vocab=4096, d_ff=1536)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(seed))
    eng = ServingEngine(model, params, num_pages=2048, page_size=16,
                        decode_buckets=(batch,), backend=backend)
    rng = np.random.RandomState(seed)
    # the prefill prompt must outlast warmup + timing at every K so the
    # chunk never completes inside a timed window (uniform per-round work)
    p_len = (max(ks) + total_steps + 1) * sub_tokens
    dec_prompts = [list(rng.randint(0, cfg.vocab_size, prompt_len))
                   for _ in range(batch)]
    pf_prompt = list(rng.randint(0, cfg.vocab_size, p_len))
    tok_per_s: dict[int, float] = {}
    syncs_per_dispatch: dict[int, float] = {}
    streams: dict[int, list[list[int]]] = {}
    for K in ks:
        # fresh residents per K, same prompts, so every variant runs the
        # identical workload from the same state
        for rid in list(eng.requests):
            eng.cache.free(rid)
        eng.requests.clear()
        eng.token_buf.clear()
        eng.chunk_state.clear()
        rids = []
        for prompt in dec_prompts:
            r = Request(Kind.OFFLINE, 0.0, prompt_len, 10 ** 6)
            eng.add_request(r, prompt)
            eng.prefill(r.rid)
            rids.append(r.rid)
        pf = Request(Kind.OFFLINE, 0.0, p_len, 10 ** 6)
        eng.add_request(pf, pf_prompt)
        # pre-claim pages to the end of the run so the padded table shapes
        # (and thus the jit cache entry) stay fixed across timed rounds
        eng.cache.ensure(pf.rid, p_len)
        for rid in rids:
            eng.cache.ensure(rid, prompt_len + max(ks) + total_steps + 1)
        # warm/compile, advancing every variant by the same max(ks)
        # iterations so the timed windows cover identical context ranges
        n = 0
        while n < max(ks):
            if K == 1:
                eng.mixed_step(rids, pf.rid, sub_tokens)
                n += 1
            else:
                eng.mixed_horizon(rids, pf.rid, sub_tokens * K, K)
                n += K
        n, dispatches = 0, 0
        syncs0 = eng.stats.host_syncs
        t0 = time.perf_counter()
        while n < total_steps:
            if K == 1:
                eng.mixed_step(rids, pf.rid, sub_tokens)
                n += 1
            else:
                eng.mixed_horizon(rids, pf.rid, sub_tokens * K, K)
                n += K
            dispatches += 1
        dt = time.perf_counter() - t0
        # one device->host sync per dispatch, K iterations amortized onto it
        syncs_per_dispatch[K] = (eng.stats.host_syncs - syncs0) / dispatches
        assert syncs_per_dispatch[K] == 1.0, syncs_per_dispatch[K]
        assert eng.prefill_progress(pf.rid) < p_len, "chunk finished mid-run"
        tok_per_s[K] = (batch + sub_tokens) * n / dt
        streams[K] = [eng.token_buf[r][:] for r in rids]
    for K in ks:
        assert streams[K] == streams[ks[0]], \
            f"greedy parity broken: K={K} diverges from K={ks[0]}"
    lo, hi = min(ks), max(ks)
    t_lo = (batch + sub_tokens) / tok_per_s[lo]
    t_hi = (batch + sub_tokens) / tok_per_s[hi]
    implied_ov = max((t_lo - t_hi) / (1.0 / lo - 1.0 / hi), 0.0)
    work = max(t_lo - implied_ov / lo, 1e-9)
    pm = PerfModel(cfg, cpu_measured())
    mid = prompt_len + max(ks) + total_steps // 2
    suggested = pm.suggest_mixed_horizon(
        sub_tokens * hi, (max(ks) + total_steps // 2 + 1) * sub_tokens,
        [mid] * batch, dispatch_overhead=implied_ov, max_horizon=max(ks))
    mh = donation_report(
        lower_mixed_horizon_step(
            eng, bucket=batch,
            pages=eng.pad_pages(eng.cache.pages_for(
                prompt_len + max(ks) + total_steps + 1)),
            chunk_bucket=eng.pad_chunk(sub_tokens),
            chunk_pages=eng.pad_pages(eng.cache.pages_for(p_len)), steps=4),
        eng.cache.k_pool.shape)
    out = {
        "backend": eng.backend,
        "batch": batch,
        "sub_chunk_tokens": sub_tokens,
        "tokens_per_s_by_k": {str(k): tok_per_s[k] for k in ks},
        "implied_dispatch_overhead_ms": implied_ov * 1e3,
        "dispatch_overhead_fraction": implied_ov / (implied_ov + work),
        "syncs_per_dispatch": syncs_per_dispatch[max(ks)],
        "suggested_k": suggested,
        "fused_speedup": tok_per_s[hi] / tok_per_s[1],
        "parity_ks_checked": list(ks),
        "mixed_horizon_donated_args": mh["donated_args"],
        "mixed_horizon_full_pool_copies": mh["full_pool_copies"],
    }
    if verbose:
        by_k = " ".join(f"K={k}:{v:.1f}" for k, v in tok_per_s.items())
        print(f"  mixed horizon ({eng.backend}, B={batch}+chunk"
              f"{sub_tokens}/iter): {by_k} tok/s; fused K={hi} speedup "
              f"{out['fused_speedup']:.2f}x vs serial mixed_step; dispatch "
              f"overhead {out['implied_dispatch_overhead_ms']:.1f} ms "
              f"({out['dispatch_overhead_fraction']:.0%} of a serial step); "
              f"suggested K={suggested}; 1 sync/dispatch; donation "
              f"{mh['donated_args']} aliased / {mh['full_pool_copies']} copies")
    return out
