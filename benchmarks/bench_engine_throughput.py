"""Table 6 analogue: maximum engine throughput under max-rate request push.

The paper compares vLLM@H800 / vLLM@910c / xLLM@910c to show its platform is
representative. Our platform is the JAX engine on this container's CPU: we
measure its real max-rate throughput (reduced model), and report the perf
model's *projection* of the same workload onto TPU v5e — the number the
cluster simulation uses — so the two layers of the reproduction are tied
together.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.hardware import TPU_V5E
from repro.core.perf_model import PerfModel
from repro.core.request import Kind, Request
from repro.engine.engine import ServingEngine
from repro.models.model import build_model


def run_engine_throughput(arch="qwen2.5-7b", n_requests=24, prompt_len=64,
                          output_len=32, seed=0, verbose=True, backend="auto"):
    cfg = get_config(arch).reduced(layers=4, d_model=512, vocab=4096, d_ff=1536)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(seed))
    eng = ServingEngine(model, params, num_pages=1024, page_size=16,
                        decode_buckets=(8, 16, 32), backend=backend)
    rng = np.random.RandomState(seed)
    reqs = []
    for _ in range(n_requests):
        prompt = list(rng.randint(0, cfg.vocab_size, prompt_len))
        r = Request(Kind.OFFLINE, 0.0, prompt_len, output_len)
        eng.add_request(r, prompt)
        reqs.append(r)
    # warmup compile: prefill one + a decode step
    eng.prefill(reqs[0].rid)
    eng.decode_step([reqs[0].rid])

    t0 = time.perf_counter()
    for r in reqs[1:]:
        eng.prefill(r.rid)
    while any(not r.done for r in reqs):
        eng.decode_step([r.rid for r in reqs if not r.done][:32])
    dt = time.perf_counter() - t0
    total_tokens = sum(r.prompt_len + r.generated for r in reqs[1:]) \
        + reqs[0].generated
    tput = total_tokens / dt
    # perf-model projection of the full-size model on v5e (single chip slice)
    pm = PerfModel(get_config(arch), TPU_V5E, tp=4)
    dec = pm.decode_estimate([prompt_len + output_len // 2] * 256)
    projected = 256 / dec.latency
    if verbose:
        print(f"  engine (CPU, reduced {arch}): {tput:,.0f} tok/s "
              f"({total_tokens} tokens in {dt:.1f}s)")
        print(f"  perf-model projection (v5e tp=4, batch 256 decode): "
              f"{projected:,.0f} tok/s")
    return {"cpu_tokens_per_s": tput, "v5e_projected_decode_tokens_per_s": projected}
