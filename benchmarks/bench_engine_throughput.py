"""Table 6 analogue: maximum engine throughput under max-rate request push.

The paper compares vLLM@H800 / vLLM@910c / xLLM@910c to show its platform is
representative. Our platform is the JAX engine on this container's CPU: we
measure its real max-rate throughput (reduced model), and report the perf
model's *projection* of the same workload onto TPU v5e — the number the
cluster simulation uses — so the two layers of the reproduction are tied
together.

``run_fused_vs_serial`` adds the chunked-prefill comparison in the regime
chunking exists for — a resident decode batch streaming tokens while a new
prompt lands chunk by chunk:

* ``serialized`` — each prefill chunk is its own dispatch followed by a
  separate decode dispatch (the residents stall while the chunk runs —
  prefill-then-decode serialization at chunk granularity).
* ``fused`` — one ``mixed_step`` dispatch lands the chunk AND decodes the
  residents (donated KV pools on both paths); decode never stalls.

Both modes run identical math (same chunks, same decode steps); the paired
interleaved trials + medians make the comparison robust to host noise. The
report includes the fused-path donation proof from the lowered HLO
(2 aliased pool args, no full-pool copies) — the record behind the
mixed-step row of ``BENCH_engine.json``.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.hardware import TPU_V5E
from repro.core.perf_model import PerfModel
from repro.core.request import Kind, Request
from repro.engine.engine import ServingEngine
from repro.models.model import build_model


def _built(arch, seed):
    cfg = get_config(arch).reduced(layers=4, d_model=512, vocab=4096, d_ff=1536)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def _engine(cfg, model, params, backend, kernels_from=None):
    return ServingEngine(model, params, num_pages=1024, page_size=16,
                         decode_buckets=(8, 16, 32), backend=backend,
                         kernels_from=kernels_from)


def _requests(eng, cfg, n, prompt_len, output_len, seed):
    rng = np.random.RandomState(seed)
    reqs = []
    for _ in range(n):
        prompt = list(rng.randint(0, cfg.vocab_size, prompt_len))
        r = Request(Kind.OFFLINE, 0.0, prompt_len, output_len)
        eng.add_request(r, prompt)
        reqs.append(r)
    return reqs


def _paired_rounds(eng, cfg, *, residents=8, trials=8, prompt_len=64,
                   chunk=16, seed=1):
    """Chunked-serving comparison, drift-robust: each trial lands one
    ``prompt_len`` prompt in ``chunk``-token pieces while ``residents``
    decode, through both schedules back to back on the same engine —
    serialized = each chunk is its own dispatch followed by a separate
    decode dispatch (residents stall during the chunk), fused = one
    ``mixed_step`` dispatch does both. Identical math lands either way
    (same chunks, same decode steps); the fused win is the dispatch fusion
    the mixed step exists for. One-output prompts free their pages on
    completion, so engine state stays comparable across trials. Returns
    (median_serial_seconds, median_fused_seconds, tokens_per_trial)."""
    assert prompt_len % chunk == 0
    n_chunks = prompt_len // chunk
    res = _requests(eng, cfg, residents, prompt_len, 10 ** 6, seed)
    for r in res:
        eng.prefill(r.rid)
    rids = [r.rid for r in res]
    # warm pass mirrors one trial exactly, compiling every variant
    warm = _requests(eng, cfg, 2, prompt_len, 1, seed + 1)
    for _ in range(n_chunks):
        eng.mixed_step([], warm[0].rid, chunk)
        eng.decode_step(rids)
    for _ in range(n_chunks):
        eng.mixed_step(rids, warm[1].rid, chunk)
    serial_dts, fused_dts = [], []
    for i in range(trials):
        a, b = _requests(eng, cfg, 2, prompt_len, 1, seed + 2 + i)
        t0 = time.perf_counter()
        for _ in range(n_chunks):
            eng.mixed_step([], a.rid, chunk)   # chunk-only prefill dispatch
            eng.decode_step(rids)              # residents stalled until here
        t1 = time.perf_counter()
        for _ in range(n_chunks):
            eng.mixed_step(rids, b.rid, chunk)
        t2 = time.perf_counter()
        serial_dts.append(t1 - t0)
        fused_dts.append(t2 - t1)
        assert a.done and b.done
    tokens = n_chunks * residents + prompt_len + 1
    return (float(np.median(serial_dts)), float(np.median(fused_dts)),
            tokens)


def run_engine_throughput(arch="qwen2.5-7b", n_requests=24, prompt_len=64,
                          output_len=32, seed=0, verbose=True, backend="auto"):
    """Closed-batch max-rate throughput — the BENCH_engine.json trajectory
    metric (kept workload-identical across PRs)."""
    cfg, model, params = _built(arch, seed)
    eng = _engine(cfg, model, params, backend)
    reqs = _requests(eng, cfg, n_requests, prompt_len, output_len, seed)
    # warmup compile: prefill one + a decode step
    eng.prefill(reqs[0].rid)
    eng.decode_step([reqs[0].rid])
    t0 = time.perf_counter()
    for r in reqs[1:]:
        eng.prefill(r.rid)
    while any(not r.done for r in reqs):
        eng.decode_step([r.rid for r in reqs if not r.done][:32])
    dt = time.perf_counter() - t0
    total_tokens = sum(r.prompt_len + r.generated for r in reqs[1:]) \
        + reqs[0].generated
    tput = total_tokens / dt
    # perf-model projection of the full-size model on v5e (single chip slice)
    pm = PerfModel(get_config(arch), TPU_V5E, tp=4)
    dec = pm.decode_estimate([prompt_len + output_len // 2] * 256)
    projected = 256 / dec.latency
    if verbose:
        print(f"  engine (CPU, reduced {arch}): {tput:,.0f} tok/s "
              f"({total_tokens} tokens in {dt:.1f}s)")
        print(f"  perf-model projection (v5e tp=4, batch 256 decode): "
              f"{projected:,.0f} tok/s")
    return {"cpu_tokens_per_s": tput,
            "v5e_projected_decode_tokens_per_s": projected}


def mixed_donation_report(eng: ServingEngine) -> dict:
    """Donation proof for the fused mixed step, from the lowered HLO: the
    two pool args must alias outputs and no full-pool-shaped copy may
    survive compilation."""
    import jax.numpy as jnp
    fn = eng._mixed_fn(8, 8, 64, 4)
    zi = jnp.zeros((8,), jnp.int32)
    lowered = fn.lower(
        eng.params, zi, zi, jnp.zeros((8, 8), jnp.int32),
        jnp.ones((8,), jnp.int32), jnp.zeros((64,), jnp.int32),
        jnp.zeros((2,), jnp.int32), jnp.zeros((4,), jnp.int32),
        eng.cache.k_pool, eng.cache.v_pool, jax.random.PRNGKey(0),
        jnp.int32(0), jnp.zeros((9,), jnp.float32), jnp.zeros((9,), jnp.int32))
    donated = lowered.as_text().count("tf.aliasing_output")
    dims = ",".join(map(str, eng.cache.k_pool.shape))
    hlo = lowered.compile().as_text()
    copies = sum(1 for line in hlo.splitlines()
                 if "copy(" in line and f"[{dims}]" in line)
    return {"mixed_donated_args": donated, "mixed_full_pool_copies": copies}


def run_fused_vs_serial(arch="qwen2.5-7b", residents=8, trials=8,
                        prompt_len=64, chunk=16, seed=0, verbose=True,
                        backend="auto"):
    """Identical chunked-serving work through both schedules (interleaved
    paired trials — robust to host noise) + the fused donation proof."""
    cfg, model, params = _built(arch, seed)
    eng = _engine(cfg, model, params, backend)
    t_serial, t_fused, tokens = _paired_rounds(
        eng, cfg, residents=residents, trials=trials, prompt_len=prompt_len,
        chunk=chunk, seed=seed + 1)
    don = mixed_donation_report(eng)
    out = {
        "serial_tokens_per_s": tokens / t_serial,
        "fused_tokens_per_s": tokens / t_fused,
        "fused_speedup": t_serial / t_fused,
        **don,
    }
    if verbose:
        print(f"  mixed-step streaming: fused {out['fused_tokens_per_s']:,.0f} vs "
              f"serial {out['serial_tokens_per_s']:,.0f} tok/s "
              f"({out['fused_speedup']:.2f}x; donated="
              f"{don['mixed_donated_args']} "
              f"pool_copies={don['mixed_full_pool_copies']})")
    return out
