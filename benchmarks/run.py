"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per the repo convention.
  PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME] [--gate]

Exit code: non-zero if any bench errored (rows print ``ERROR ...``) or, with
``--gate``, if any regression gate trips. Gated rows report failures
uniformly via ``_gate_check``: the row prints
``ERROR gate failed [<gate>=<measured> (want <op> <threshold>); ...]:`` so a
red CI line names exactly which bound tripped and by how much. Speedup
floors (engine throughput, decode horizon, fused mixed horizon) derive from
the recorded ``BENCH_engine.json`` trajectory.
"""
from __future__ import annotations

import argparse
import json
import os
import time

_ERRORS: list[str] = []


def _row(name, us, derived):
    if str(derived).startswith("ERROR"):
        _ERRORS.append(name)
    print(f"{name},{us:.1f},{derived}", flush=True)


_GATE_OPS = {">=": lambda m, t: m >= t, "<=": lambda m, t: m <= t,
             "==": lambda m, t: m == t}


def _gate_check(gates) -> str:
    """Uniform gate reporting: ``gates`` is a list of
    ``(gate_name, measured, op, threshold)``. Returns an ``ERROR``-prefixed
    row prefix naming EVERY failed gate with its threshold and measured
    value (so a red CI row says exactly which bound tripped and by how
    much), or '' when all gates hold. ``None`` measurements fail closed."""
    fails = [f"{name}={'none' if m is None else f'{m:g}'} (want {op} {t:g})"
             for name, m, op, t in gates
             if m is None or not _GATE_OPS[op](m, t)]
    return f"ERROR gate failed [{'; '.join(fails)}]: " if fails else ""


def engine_throughput_floor(fraction: float = 0.25) -> float:
    """Regression floor: a fraction of the last recorded cpu_tokens_per_s
    (CI machines are slower and noisier than the recording host, but a real
    hot-path regression is 2-10x, far below this floor)."""
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_engine.json")
    with open(path) as f:
        rec = json.load(f)
    return fraction * rec["trajectory"][-1]["cpu_tokens_per_s"]


def horizon_speedup_floor(fraction: float = 0.25) -> float:
    """Multi-step regression floor: the K=16 horizon must keep at least
    ``fraction`` of the recorded K=16-vs-K=1 speedup margin (noise-tolerant,
    but losing the fused dispatch entirely — speedup -> 1.0x — fails)."""
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_engine.json")
    with open(path) as f:
        rec = json.load(f)
    recorded = next(r["decode_horizon"]["k16_speedup"]
                    for r in reversed(rec["trajectory"])
                    if "decode_horizon" in r)
    return 1.0 + fraction * (recorded - 1.0)


def mixed_horizon_speedup_floor(fraction: float = 0.25) -> float:
    """Fused mixed-horizon regression floor: the K=16 fused dispatch must
    keep at least ``fraction`` of the recorded fused-vs-serial speedup
    margin over ``mixed_step`` (same noise tolerance as the decode-horizon
    floor; losing the fusion entirely — speedup -> 1.0x — fails)."""
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_engine.json")
    with open(path) as f:
        rec = json.load(f)
    recorded = next(r["mixed_horizon"]["fused_speedup"]
                    for r in reversed(rec["trajectory"])
                    if "mixed_horizon" in r)
    return 1.0 + fraction * (recorded - 1.0)


def bench_traces(quick=False):
    from benchmarks.bench_traces import run_scaling_invariance, run_traces
    t0 = time.perf_counter()
    rows = run_traces(duration=300 if quick else 600)
    for ds, s in rows.items():
        _row(f"table5_{ds}", (time.perf_counter() - t0) * 1e6 / max(len(rows), 1),
             f"avg_prompt={s['avg_prompt']:.0f}(target {s['target_prompt']:.0f}) "
             f"avg_output={s['avg_output']:.0f}(target {s['target_output']:.0f}) "
             f"peak/mean={s.get('peak_over_mean', 0):.1f}")
    inv = run_scaling_invariance(duration=300 if quick else 600)
    for k in ("x0.5", "x2.0"):
        _row(f"fig1_scaling_{k}", 0.0,
             f"rate_ratio={inv[k]['rate_ratio']:.2f} "
             f"burstiness_ratio={inv[k]['burstiness_ratio']:.2f}(want ~1)")


def bench_roofline_scatter(quick=False):
    from benchmarks.bench_roofline_scatter import run_scatter, saturation_points
    t0 = time.perf_counter()
    rows = run_scatter()
    us = (time.perf_counter() - t0) * 1e6 / len(rows)
    sat = saturation_points()
    for r in rows[:6] + rows[-6:]:
        _row(f"fig3_{r['kind']}_b{r['batch']}_l{r['len']}", us,
             f"AI={r['arith_intensity']:.1f} "
             f"achieved={r['achieved_tflops']:.1f}TF/s "
             f"lat={r['latency_ms']:.2f}ms bn={r['bottleneck']}")
    _row("fig3_saturation", us,
         f"prefill_sat_tokens={sat['prefill_compute_saturation_tokens']} "
         f"decode_bs_sat={sat['decode_bs_sat']} (paper: ~250-300 on 910c)")


def bench_perfmodel_accuracy(quick=False):
    from benchmarks.bench_perfmodel_accuracy import run_accuracy
    t0 = time.perf_counter()
    mae, hw = run_accuracy(verbose=not quick)
    _row("sec332_perfmodel_mae", (time.perf_counter() - t0) * 1e6,
         f"held_out_MAPE={mae:.1%} (paper claims ~5% on 910c) "
         f"fit:F={hw.F_g:.3g}FLOP/s M={hw.M_g:.3g}B/s O_p={hw.O_p*1e3:.1f}ms "
         f"O_d={hw.O_d*1e3:.1f}ms")


def bench_engine_throughput(quick=False, gate=False):
    from benchmarks.bench_engine_throughput import (run_engine_throughput,
                                                    run_fused_vs_serial)
    t0 = time.perf_counter()
    r = run_engine_throughput(n_requests=8 if quick else 24, verbose=not quick)
    err = _gate_check([("cpu_tokens_per_s", r["cpu_tokens_per_s"], ">=",
                        engine_throughput_floor())]) if gate else ""
    _row("table6_engine_throughput", (time.perf_counter() - t0) * 1e6,
         err + f"cpu={r['cpu_tokens_per_s']:.0f}tok/s "
         f"v5e_projected={r['v5e_projected_decode_tokens_per_s']:.0f}tok/s")
    t0 = time.perf_counter()
    m = run_fused_vs_serial(trials=4 if quick else 8, verbose=not quick)
    err = _gate_check([
        ("fused_speedup", m["fused_speedup"], ">=", 1.0),
        ("donated_args", m["mixed_donated_args"], ">=", 2),
        ("full_pool_copies", m["mixed_full_pool_copies"], "<=", 0),
    ]) if gate else ""
    _row("table6_mixed_step", (time.perf_counter() - t0) * 1e6,
         err + f"fused={m['fused_tokens_per_s']:.0f} "
         f"serial={m['serial_tokens_per_s']:.0f}tok/s "
         f"speedup={m['fused_speedup']:.2f}x "
         f"donated={m['mixed_donated_args']} "
         f"pool_copies={m['mixed_full_pool_copies']}")


def bench_decode_hotpath(quick=False, gate=False):
    """Zero-copy decode hot path: steps/s, host overhead, donation proof,
    multi-step decode-horizon amortization (gated on the recorded K=16
    speedup and on the horizon scan's pool donation)."""
    from benchmarks.bench_decode_hotpath import (
        run_decode_hotpath, run_horizon_amortization,
        run_mixed_horizon_amortization)
    t0 = time.perf_counter()
    r = run_decode_hotpath(steps=10 if quick else 30, verbose=not quick)
    err = _gate_check([
        ("decode_donated_args", r["decode_donated_args"], ">=", 2),
        ("decode_full_pool_copies", r["decode_full_pool_copies"], "<=", 0),
        ("prefill_full_pool_copies", r["prefill_full_pool_copies"], "<=", 0),
    ]) if gate else ""
    _row("decode_hotpath", (time.perf_counter() - t0) * 1e6,
         err + f"steps_per_s={r['steps_per_s']:.1f} "
         f"host_overhead_ms={r['host_overhead_ms_per_step']:.2f} "
         f"({r['host_overhead_fraction']:.0%}) "
         f"donated={r['decode_donated_args']} "
         f"pool_copies={r['decode_full_pool_copies']}"
         f"+{r['prefill_full_pool_copies']} backend={r['backend']}")
    t0 = time.perf_counter()
    h = run_horizon_amortization(total_steps=32 if quick else 64,
                                 verbose=not quick)
    err = _gate_check([
        ("horizon_k16_speedup", h["k16_speedup"], ">=",
         horizon_speedup_floor()),
        ("donated_args", h["horizon_donated_args"], ">=", 2),
        ("full_pool_copies", h["horizon_full_pool_copies"], "<=", 0),
    ]) if gate else ""
    ks = " ".join(f"k{k}={v:.0f}" for k, v in h["tokens_per_s_by_k"].items())
    _row("decode_horizon", (time.perf_counter() - t0) * 1e6,
         err + f"{ks} tok/s suggested_k={h['suggested_k']} "
         f"k16_speedup={h['k16_speedup']:.2f}x "
         f"donated={h['horizon_donated_args']} "
         f"pool_copies={h['horizon_full_pool_copies']}")
    t0 = time.perf_counter()
    mh = run_mixed_horizon_amortization(total_steps=32 if quick else 64,
                                        verbose=not quick)
    err = _gate_check([
        ("mixed_horizon_fused_speedup", mh["fused_speedup"], ">=",
         mixed_horizon_speedup_floor()),
        ("donated_args", mh["mixed_horizon_donated_args"], ">=", 2),
        ("full_pool_copies", mh["mixed_horizon_full_pool_copies"], "<=", 0),
        ("syncs_per_dispatch", mh["syncs_per_dispatch"], "==", 1),
    ]) if gate else ""
    ks = " ".join(f"k{k}={v:.0f}" for k, v in mh["tokens_per_s_by_k"].items())
    _row("mixed_horizon", (time.perf_counter() - t0) * 1e6,
         err + f"{ks} tok/s fused_speedup={mh['fused_speedup']:.2f}x "
         f"suggested_k={mh['suggested_k']} "
         f"syncs_per_dispatch={mh['syncs_per_dispatch']:.0f} "
         f"donated={mh['mixed_horizon_donated_args']} "
         f"pool_copies={mh['mixed_horizon_full_pool_copies']}")


def bench_colocation(quick=False, gate=False):
    from benchmarks.bench_colocation import (run_chaos_replay,
                                             run_colocation,
                                             run_datacenter_replay,
                                             run_prefix_reuse,
                                             run_runtime_policy_comparison,
                                             summarize)
    # real pool-runtime replay (virtual clock, deterministic) — the policy
    # regression gate; the simulator sweep below reproduces Fig. 6
    t0 = time.perf_counter()
    rt = run_runtime_policy_comparison(quick=quick, verbose=not quick)
    pol = rt["policies"]
    _row("fig6_runtime_replay", (time.perf_counter() - t0) * 1e6,
         f"attain(base_pd/op/ooco)="
         f"{pol['base_pd']['online_slo_attainment']:.2f}/"
         f"{pol['online_priority']['online_slo_attainment']:.2f}/"
         f"{pol['ooco']['online_slo_attainment']:.2f} "
         f"offline_tok/s={pol['base_pd']['offline_tokens_per_s']:.0f}/"
         f"{pol['online_priority']['offline_tokens_per_s']:.0f}/"
         f"{pol['ooco']['offline_tokens_per_s']:.0f} "
         f"ooco_vs_op={rt['ooco_vs_online_priority_offline_tput']}x")
    # chaos replay: one relaxed engine crashed mid-trace via deterministic
    # fault injection — online SLO attainment must hold at 100% and the
    # offline throughput loss must be reported, never silent
    t0 = time.perf_counter()
    ch = run_chaos_replay(quick=quick, verbose=not quick)
    crun = ch["runs"]["chaos"]
    err = _gate_check([
        ("online_slo_attainment", crun["online_slo_attainment"], ">=", 1.0),
        ("engine_crashes", crun["engine_crashes"], "==", 1),
    ]) if gate else ""
    _row("fig6_chaos_replay", (time.perf_counter() - t0) * 1e6,
         err + f"attain={crun['online_slo_attainment']:.2f} "
         f"crashes={crun['engine_crashes']} "
         f"recoveries={crun['recoveries']} "
         f"offline_tput_loss={ch['offline_tput_loss']:.2f} "
         f"plan={ch['fault_plan']}")
    # cross-request KV reuse: shared-prefix trace, radix prefix cache on vs
    # off — effective prefill throughput must improve >= 3x (recorded run:
    # >= 5x) with bit-exact greedy token parity (asserted inside)
    t0 = time.perf_counter()
    pr = run_prefix_reuse(quick=quick, verbose=not quick)
    err = _gate_check([
        ("effective_prefill_speedup", pr["effective_prefill_speedup"],
         ">=", 3.0),
        ("token_parity", int(pr["token_parity"]), "==", 1),
    ]) if gate else ""
    _row("prefix_reuse", (time.perf_counter() - t0) * 1e6,
         err + f"eff_prefill_speedup={pr['effective_prefill_speedup']:.2f}x "
         f"hit_rate={pr['hit_rate']:.2f} "
         f"cached_frac={pr['cached_token_fraction']:.2f} "
         f"token_parity={pr['token_parity']}")
    # datacenter-overhead replay: replay_hw('v5e') charges real v5e
    # dispatch overheads, where horizon fusion pays — full ooco must keep
    # >= online_priority offline throughput at 100% online SLO attainment
    # while actually firing fused mixed-horizon rounds
    t0 = time.perf_counter()
    dc = run_datacenter_replay(quick=quick, verbose=not quick)
    err = _gate_check([
        ("ooco_online_slo_attainment",
         dc["policies"]["ooco"]["online_slo_attainment"], ">=", 1.0),
        ("ooco_vs_online_priority_offline_tput",
         dc["ooco_vs_online_priority_offline_tput"], ">=", 1.0),
        ("mixed_horizon_rounds", dc["mixed_horizon_rounds"], ">=", 1),
    ]) if gate else ""
    _row("datacenter_replay", (time.perf_counter() - t0) * 1e6,
         err + f"hw={dc['hw']} attain(op/ooco_h1/ooco)="
         f"{dc['policies']['online_priority']['online_slo_attainment']:.2f}/"
         f"{dc['policies']['ooco_h1']['online_slo_attainment']:.2f}/"
         f"{dc['policies']['ooco']['online_slo_attainment']:.2f} "
         f"offline_tok/s="
         f"{dc['policies']['online_priority']['offline_tokens_per_s']:.0f}/"
         f"{dc['policies']['ooco_h1']['offline_tokens_per_s']:.0f}/"
         f"{dc['policies']['ooco']['offline_tokens_per_s']:.0f} "
         f"ooco_vs_op={dc['ooco_vs_online_priority_offline_tput']}x "
         f"vs_h1={dc['ooco_vs_horizon1_offline_tput']}x "
         f"mixed_horizon_rounds={dc['mixed_horizon_rounds']}")
    t0 = time.perf_counter()
    datasets = ("ooc",) if quick else ("ooc", "azure_conv", "azure_code")
    results = run_colocation(duration=120 if quick else 180,
                             datasets=datasets, verbose=not quick)
    if not quick:  # the paper's second model: 72B on a TP-16 instance
        results += run_colocation(arch="qwen2.5-72b", datasets=("ooc",),
                                  duration=180, tp=16, verbose=False)
    us = (time.perf_counter() - t0) * 1e6
    for ds, tputs, ratio in summarize(results):
        _row(f"fig6_{ds}", us / max(len(datasets), 1),
             f"base_pd={tputs['base_pd']:.0f} "
             f"online_priority={tputs['online_priority']:.0f} "
             f"ooco={tputs['ooco']:.0f}tok/s "
             f"ooco_vs_best_baseline={ratio:.2f}x (paper: 1.17-3x)")


def bench_gateway(quick=False, gate=False):
    """Live-gateway load harness (PR 9): >= 200 concurrent streams with
    seeded bursts, >= 10% mid-stream disconnects, a deadline mix, and a
    deterministic backpressure probe — clean and chaos (relaxed-engine
    crash) variants. The harness hard-asserts the terminal-state partition
    and the zero-leak drain internally; with ``--gate`` the p99 SLO bounds
    and leak counter additionally fail the run."""
    from benchmarks.bench_gateway import SLO_TPOT, SLO_TTFT, run_gateway_load
    t0 = time.perf_counter()
    res = run_gateway_load(quick=quick, verbose=not quick)
    us = (time.perf_counter() - t0) * 1e6 / max(len(res), 1)
    for name, r in res.items():
        err = _gate_check([
            ("leaked_pages", r["leaked_pages"], "<=", 0),
            ("ttft_p99", r["ttft_p99"] or 0, "<=", SLO_TTFT),
            ("tpot_p99", r["tpot_p99"] or 0, "<=", SLO_TPOT),
        ]) if gate else ""
        _row(f"gateway_{name}", us,
             err + f"streams={r['n_streams']} fin={r['finished']} "
             f"cancel={r['cancelled']} deadline={r['deadline']} "
             f"rej={r['rejected']} ttft_p99={r['ttft_p99']:.2f}s "
             f"tpot_p99={r['tpot_p99']:.3f}s leaked={r['leaked_pages']} "
             f"crashes={r['engine_crashes']} recoveries={r['recoveries']}")


def bench_pool_ratio(quick=False):
    """Beyond-paper: sensitivity of max offline throughput to the
    relaxed:strict pool ratio (paper only evaluates 1+1)."""
    from benchmarks.bench_pool_ratio import run_pool_ratio, sensitivity
    t0 = time.perf_counter()
    rows = run_pool_ratio(duration=90 if quick else 150, verbose=not quick)
    sens = sensitivity(rows)
    us = (time.perf_counter() - t0) * 1e6
    for policy, s in sens.items():
        _row(f"pool_ratio_{policy}", us / 2,
             f"best={s['best']:.0f} worst={s['worst']:.0f} tok/s "
             f"sensitivity={s['sensitivity']:.2f}x across P:D ratios")


def bench_kernels(quick=False):
    """Kernel wrapper timing (CPU): flash-xla vs naive reference."""
    import jax
    import jax.numpy as jnp
    from repro.models.attention import flash_attention_xla, naive_attention_xla
    rng = jax.random.PRNGKey(0)
    q = jax.random.normal(rng, (2, 1024, 8, 64), jnp.bfloat16)
    k = jax.random.normal(rng, (2, 1024, 4, 64), jnp.bfloat16)
    v = jax.random.normal(rng, (2, 1024, 4, 64), jnp.bfloat16)
    for name, fn in [("flash_xla", flash_attention_xla),
                     ("naive_xla", naive_attention_xla)]:
        f = jax.jit(lambda q, k, v, fn=fn: fn(q, k, v, causal=True))
        f(q, k, v).block_until_ready()
        t0 = time.perf_counter()
        n = 3 if quick else 10
        for _ in range(n):
            f(q, k, v).block_until_ready()
        _row(f"kernel_{name}_prefill_1k", (time.perf_counter() - t0) / n * 1e6,
             "causal attention 2x1024x8x64 (CPU)")


BENCHES = {
    "traces": bench_traces,
    "roofline_scatter": bench_roofline_scatter,
    "kernels": bench_kernels,
    "engine_throughput": bench_engine_throughput,
    "decode_hotpath": bench_decode_hotpath,
    "perfmodel_accuracy": bench_perfmodel_accuracy,
    "colocation": bench_colocation,
    "gateway": bench_gateway,
    "pool_ratio": bench_pool_ratio,
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--gate", action="store_true",
                    help="fail (exit 1) if any regression gate trips "
                         "(throughput / horizon / mixed-horizon floors from "
                         "BENCH_engine.json, donation, SLO, leak, parity); "
                         "each failing row names the gate, its threshold, "
                         "and the measured value")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, fn in BENCHES.items():
        if args.only and args.only != name:
            continue
        kw = ({"gate": args.gate}
              if name in ("engine_throughput", "decode_hotpath",
                          "colocation", "gateway") else {})
        try:
            fn(quick=args.quick, **kw)
        except Exception as e:  # keep the harness running
            import traceback
            traceback.print_exc()
            _row(name, 0.0, f"ERROR {type(e).__name__}: {e}")
    if _ERRORS:
        print(f"FAILED benches: {','.join(_ERRORS)}", flush=True)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
