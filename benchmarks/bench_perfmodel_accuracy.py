"""§3.3.2 validation: the roofline latency model's accuracy (paper: ≈5 %).

We cannot time an Ascend 910c, so we do what the paper did on *this*
platform: profile a small set of calibration runs of the REAL JAX engine on
CPU, fit the Table-4 parameters (F_*, M_*, O_p, O_d) by least squares over
the model's own FLOPs/bytes terms, and report mean absolute percentage
error on held-out configurations.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.hardware import cpu_measured
from repro.core.perf_model import HardwareParams, PerfModel
from repro.core.request import Kind, Request
from repro.engine.engine import ServingEngine
from repro.models.model import build_model


def _measure(engine, cfg, kind, size, ctx, reps=3):
    """Median wall time of a prefill(size tokens) or decode(batch=size)."""
    rng = np.random.RandomState(0)
    if kind == "prefill":
        times = []
        for i in range(reps):
            prompt = list(rng.randint(0, cfg.vocab_size, size))
            r = Request(Kind.ONLINE, 0.0, size, 2)
            engine.add_request(r, prompt)
            t0 = time.perf_counter()
            engine.prefill(r.rid)
            times.append(time.perf_counter() - t0)
            engine.cache.free(r.rid)
        return float(np.median(times))
    # decode: build `size` requests with ~ctx context
    rids = []
    for _ in range(size):
        prompt = list(rng.randint(0, cfg.vocab_size, ctx))
        r = Request(Kind.ONLINE, 0.0, ctx, 64)
        engine.add_request(r, prompt)
        engine.prefill(r.rid)
        rids.append(r.rid)
    engine.decode_step(rids)  # warm the jit cache
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        engine.decode_step(rids)
        times.append(time.perf_counter() - t0)
    for rid in rids:
        engine.cache.free(rid)
    return float(np.median(times))


def _terms(pm: PerfModel, kind, size, ctx):
    """(gemm_flops, gemm_bytes, attn_flops, attn_bytes) for the workload."""
    est = (pm.prefill_estimate([size]) if kind == "prefill"
           else pm.decode_estimate([ctx] * size, detail=True))
    gf = sum(o.flops for o in est.ops if o.kind == "gemm")
    gb = sum(o.bytes for o in est.ops if o.kind == "gemm")
    af = sum(o.flops for o in est.ops if o.kind.startswith("attn"))
    ab = sum(o.bytes for o in est.ops if o.kind.startswith("attn"))
    return gf, gb, af, ab


def run_accuracy(arch="qwen2.5-7b", seed=0, verbose=True):
    cfg = get_config(arch).reduced(layers=4, d_model=512, vocab=4096, d_ff=1536)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(seed))
    engine = ServingEngine(model, params, num_pages=2048, page_size=16,
                           decode_buckets=(1, 2, 4, 8, 16, 32))
    cases = [("prefill", 64, 0), ("prefill", 128, 0), ("prefill", 256, 0),
             ("prefill", 512, 0),
             ("decode", 1, 64), ("decode", 4, 64), ("decode", 8, 128),
             ("decode", 16, 128), ("decode", 32, 256)]
    pm0 = PerfModel(cfg, cpu_measured())
    rows = []
    for kind, size, ctx in cases:
        t = _measure(engine, cfg, kind, size, ctx)
        rows.append((kind, size, ctx, t, _terms(pm0, kind, size, ctx)))

    # least squares fit of [1/F, 1/M, O_p, O_d] over latency = gf/F + max... ;
    # on CPU there is no separate attention unit, so fit a single F and M
    # with Eq. 1 linearized as  t ≈ flops/F + bytes/M + O_kind
    A, y = [], []
    for kind, size, ctx, t, (gf, gb, af, ab) in rows:
        A.append([gf + af, gb + ab, 1.0 if kind == "prefill" else 0.0,
                  0.0 if kind == "prefill" else 1.0])
        y.append(t)
    coef, *_ = np.linalg.lstsq(np.asarray(A), np.asarray(y), rcond=None)
    inv_F, inv_M, O_p, O_d = [max(c, 1e-15) for c in coef]
    hw = HardwareParams(name="cpu_fit", F_g=1 / inv_F, F_ap=1 / inv_F,
                        F_ad=1 / inv_F, M_g=1 / inv_M, M_a=1 / inv_M,
                        O_p=max(O_p, 0.0), O_d=max(O_d, 0.0), B_c=1e9,
                        hbm_capacity=8e9, peak_flops=1 / inv_F,
                        peak_hbm_bw=1 / inv_M)
    pm = PerfModel(cfg, hw)

    # held-out evaluation
    held = [("prefill", 96, 0), ("prefill", 384, 0), ("decode", 2, 96),
            ("decode", 8, 256), ("decode", 24, 128)]
    errs = []
    for kind, size, ctx in held:
        t = _measure(engine, cfg, kind, size, ctx)
        pred = (pm.prefill_estimate([size]).latency if kind == "prefill"
                else pm.decode_estimate([ctx] * size).latency)
        err = abs(pred - t) / t
        errs.append(err)
        if verbose:
            print(f"  {kind:8s} size={size:4d} ctx={ctx:4d} "
                  f"measured={t*1e3:7.2f}ms predicted={pred*1e3:7.2f}ms "
                  f"err={err:.1%}", flush=True)
    return float(np.mean(errs)), hw
