"""Gateway load harness: hundreds of concurrent live streams with bursty
arrivals, mid-stream disconnects, deadline mixes, and (optionally) injected
engine crashes — the PR 9 robustness gate.

What it proves, every run (hard asserts, not just reported numbers):

* **terminal-state partition** — every submitted stream reaches exactly one
  of finished / cancelled / deadline-aborted / rejected, client-side counts
  reconciled against the runtime's ``summary()`` counters;
* **zero-leak drain** — after graceful drain the allocator reports zero
  allocated pages on every live engine (KV pages cannot leak through
  cancellation, deadlines, disconnects, or crash recovery);
* **SLO structure under load** — online TTFT/TPOT p99 stay inside the
  (deliberately CPU-generous) SLOs while >= 10% of clients disconnect
  mid-stream and a slice of requests carries deadlines tight enough to
  blow.

The chaos variant reuses the PR 6 ``FaultPlan`` (a relaxed engine crashes
mid-burst) and must satisfy the same three contracts — crash recovery may
cost throughput, never correctness.

  PYTHONPATH=src python -m benchmarks.bench_gateway [--quick] [--chaos]
"""
from __future__ import annotations

import argparse
import asyncio
import time

import numpy as np

# CPU-generous SLOs: the gate is structural (p99 must stay bounded under
# churn), not a datacenter latency claim — CI machines vary 10x.
SLO_TTFT = 60.0
SLO_TPOT = 2.0


def _build_runtime(model_bundle, *, n_relaxed=1, fault_plan=None,
                   max_online_queue=256):
    from repro.cluster.runtime import PoolRuntime, WallClock
    model, params, donor = model_bundle
    return PoolRuntime(
        model.cfg, policy="ooco", n_strict=1, n_relaxed=n_relaxed,
        clock=WallClock(), slo_ttft=SLO_TTFT, slo_tpot=SLO_TPOT,
        num_pages=512, page_size=8, backend="ref",
        max_online_queue=max_online_queue, fault_plan=fault_plan,
        chaos_seed=7, model=model, params=params, kernels_from=donor)


def _model_bundle(arch: str = "qwen2.5-7b"):
    import jax

    from repro.configs import get_config
    from repro.models.model import build_model
    cfg = get_config(arch).reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return [model, params, None]


async def _run_load(gateway, *, n_streams: int, seed: int,
                    max_new_tokens: int, vocab: int) -> dict:
    """Drive ``n_streams`` concurrent clients with seeded bursty arrivals.

    Deterministically (by seed) assigns each client a role: ~15% disconnect
    mid-stream, ~10% carry a deadline tight enough to blow under load,
    ~10% carry a loose deadline they should meet, ~20% are offline."""
    from repro.cluster.gateway import AdmissionRejected
    from repro.core.request import Kind

    rng = np.random.default_rng(seed)
    n_bursts = max(n_streams // 20, 1)
    burst_at = np.sort(rng.uniform(0.0, 3.0, n_bursts))
    arrivals = np.sort(
        burst_at[rng.integers(0, n_bursts, n_streams)]
        + rng.exponential(0.05, n_streams))
    # exact role counts (the >= 10% disconnect floor is a hard guarantee,
    # not an expectation over a random draw), shuffled across arrivals
    n_disc = max(n_streams * 15 // 100, 1)
    n_tight = max(n_streams // 10, 1)
    n_loose = max(n_streams // 10, 1)
    n_off = max(n_streams // 5, 1)
    roles = (["disconnect"] * n_disc + ["deadline_tight"] * n_tight
             + ["deadline_loose"] * n_loose + ["offline"] * n_off
             + ["plain"] * (n_streams - n_disc - n_tight - n_loose - n_off))
    rng.shuffle(roles)
    prompts = rng.integers(1, vocab, (n_streams, 8))
    counts = {"finished": 0, "cancelled": 0, "deadline": 0,
              "rejected": 0, "error": 0}
    t0 = time.perf_counter()

    async def client(i: int) -> str:
        await asyncio.sleep(max(arrivals[i] - (time.perf_counter() - t0), 0))
        role = roles[i]
        kw = {"kind": Kind.OFFLINE if role == "offline" else Kind.ONLINE,
              "max_new_tokens": max_new_tokens}
        if role == "deadline_tight":
            kw["total_deadline"] = 0.2     # blows under a 200-way burst
        elif role == "deadline_loose":
            kw["total_deadline"] = 300.0   # must be met
        try:
            stream = await gateway.submit(prompts[i].tolist(), **kw)
        except AdmissionRejected:
            return "rejected"
        got = 0
        async for _tok in stream:
            got += 1
            if role == "disconnect" and got >= max(max_new_tokens // 2, 1):
                if await stream.cancel():   # client walks away mid-stream
                    return "cancelled"
                break   # lost the race: already terminal server-side
        if stream.outcome is None:
            async for _tok in stream:      # drain to the terminal event
                pass
        return stream.outcome or "error"

    outcomes = await asyncio.gather(*(client(i) for i in range(n_streams)))
    for o in outcomes:
        counts[o if o in counts else "error"] += 1
    counts["loose_deadline_missed"] = sum(
        1 for i, o in enumerate(outcomes)
        if roles[i] == "deadline_loose" and o == "deadline")
    return counts


def _probe_backpressure(gw, rt) -> tuple[int, int]:
    """Deterministic bounded-admission check: clamp the online bound to the
    current queue depth + 1 and push 4 submits under the runtime lock (so
    the scheduler cannot drain between them) — exactly one admits, three
    bounce with ``AdmissionRejected``. The admitted request runs to
    completion during drain (no client stream; counted server-side)."""
    from repro.cluster.runtime import AdmissionRejected
    from repro.core.request import Kind, Request
    ok = rej = 0
    with gw._lock:
        old = rt.max_online_queue
        rt.max_online_queue = len(rt.online_queue) + 1
        try:
            for _ in range(4):
                req = Request(Kind.ONLINE, rt.clock.now(), 8, 1)
                try:
                    rt.submit(req, [5] * 8)
                    ok += 1
                except AdmissionRejected:
                    rej += 1
        finally:
            rt.max_online_queue = old
    gw._wake.set()
    return ok, rej


async def _one_run(model_bundle, *, n_streams: int, seed: int, chaos: bool,
                   max_new_tokens: int, verbose: bool) -> dict:
    from repro.cluster.gateway import Gateway
    rt = _build_runtime(
        model_bundle,
        n_relaxed=2 if chaos else 1,
        fault_plan="crash:relaxed1@1.5" if chaos else None)
    if model_bundle[2] is None:
        model_bundle[2] = rt.kernel_donor   # share compiled kernels onward
    gw = Gateway(rt)
    await gw.start()
    # warmup: trigger the jit variants (prefill buckets, decode step) so
    # compile time never pollutes measured TTFT/TPOT percentiles
    warm = await gw.submit(list(range(1, 9)), max_new_tokens=2)
    async for _ in warm:
        pass
    with gw._lock:
        rt.clock.reset()   # t=0 is the start of the measured load phase
    counts = await _run_load(gw, n_streams=n_streams, seed=seed,
                             max_new_tokens=max_new_tokens,
                             vocab=rt.cfg.vocab_size)
    probe_ok, probe_rej = _probe_backpressure(gw, rt)
    report = await gw.drain(timeout=180.0)
    s = report["summary"]
    leaked = {k: v for k, v in report["leaked_pages"].items() if v}

    # -- hard contracts (always asserted, chaos or not) -----------------
    assert not leaked, f"KV pages leaked after graceful drain: {leaked}"
    assert counts["error"] == 0, f"streams died without a terminal state: {counts}"
    total = sum(counts[k] for k in
                ("finished", "cancelled", "deadline", "rejected"))
    assert total == n_streams, \
        f"terminal-state partition broken: {counts} != {n_streams} streams"
    # client-side terminals must reconcile with the runtime's counters
    # (server-side extras: one warmup request + the admitted backpressure
    # probes, all of which drain to completion)
    srv_finished = s["online_finished"] + s["offline_finished"]
    assert srv_finished == counts["finished"] + 1 + probe_ok, \
        f"server finished {srv_finished} != client {counts['finished']} " \
        f"+ warmup + {probe_ok} probes"
    assert s["deadline_aborts"] == counts["deadline"], (s["deadline_aborts"], counts)
    assert s["cancelled"] == counts["cancelled"], (s["cancelled"], counts)
    assert s["rejected_online"] == counts["rejected"] + probe_rej, \
        (s["rejected_online"], counts, probe_rej)
    assert probe_rej >= 1, "backpressure probe never saw AdmissionRejected"
    assert counts["loose_deadline_missed"] == 0, \
        f"loose (300s) deadlines must be met: {counts}"
    if chaos:
        assert s["engine_crashes"] == 1, s["engine_crashes"]

    out = {
        "n_streams": n_streams,
        "chaos": chaos,
        **{k: counts[k] for k in
           ("finished", "cancelled", "deadline", "rejected")},
        "ttft_p99": s["online_ttft_p99"],
        "tpot_p99": s["online_tpot_p99"],
        "slo_attainment": s["online_slo_attainment"],
        "recoveries": s["recoveries"],
        "engine_crashes": s["engine_crashes"],
        "leaked_pages": sum(report["leaked_pages"].values()),
        "elapsed": s["elapsed"],
    }
    if verbose:
        print(f"  {'chaos' if chaos else 'clean'}: {out}")
    return out


def run_gateway_load(quick: bool = False, chaos: bool = True,
                     n_streams: int = 200, seed: int = 0,
                     verbose: bool = True) -> dict:
    """Clean run (always >= 200 streams — the acceptance floor) plus a
    chaos run reusing the PR 6 fault plan. Returns both reports."""
    bundle = _model_bundle()
    max_new = 4 if quick else 6
    clean = asyncio.run(_one_run(
        bundle, n_streams=max(n_streams, 200), seed=seed, chaos=False,
        max_new_tokens=max_new, verbose=verbose))
    out = {"clean": clean}
    if chaos:
        out["chaos"] = asyncio.run(_one_run(
            bundle, n_streams=80 if quick else max(n_streams, 200),
            seed=seed + 1, chaos=True, max_new_tokens=max_new,
            verbose=verbose))
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--no-chaos", action="store_true")
    ap.add_argument("--streams", type=int, default=200)
    args = ap.parse_args()
    res = run_gateway_load(quick=args.quick, chaos=not args.no_chaos,
                           n_streams=args.streams)
    ok = all(r["leaked_pages"] == 0
             and (r["ttft_p99"] or 0) <= SLO_TTFT
             and (r["tpot_p99"] or 0) <= SLO_TPOT
             for r in res.values())
    print("PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
