"""Figure 3 reproduction: roofline scatter of prefill/decode executions.

Each point is one Prefill or Decode iteration at a given (batch, length):
arithmetic intensity vs achieved FLOP/s under the perf model, plus latency.
Reproduces the paper's qualitative structure: prefill compute-saturates past
a few hundred tokens; decode rides the memory-bandwidth roof and bends
toward compute as batch grows.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core.hardware import TPU_V5E
from repro.core.perf_model import PerfModel


def run_scatter(arch="qwen2.5-7b", tp=4):
    pm = PerfModel(get_config(arch), TPU_V5E, tp=tp)
    points = []
    for s in (32, 64, 128, 256, 512, 1024, 2048, 4096, 8192):
        est = pm.prefill_estimate([s])
        points.append(("prefill", 1, s, est))
    for b in (1, 4, 16, 64, 256, 512):
        for ctx in (256, 1024, 4096):
            est = pm.decode_estimate([ctx] * b)
            points.append(("decode", b, ctx, est))
    rows = []
    for kind, b, s, est in points:
        ai = est.flops / max(est.bytes, 1)
        achieved = est.flops / max(est.latency - est.overhead, 1e-9)
        rows.append({
            "kind": kind, "batch": b, "len": s,
            "arith_intensity": ai,
            "achieved_tflops": achieved / 1e12,
            "latency_ms": est.latency * 1e3,
            "bottleneck": est.bottleneck,
        })
    return rows


def saturation_points(arch="qwen2.5-7b", tp=4):
    """Paper §2.3 claims: prefill compute-saturates around a few hundred
    tokens; decode GEMMs turn compute-bound around batch ~300 (910c)."""
    pm = PerfModel(get_config(arch), TPU_V5E, tp=tp)
    prefill_sat = None
    for s in range(32, 4096, 32):
        if pm.prefill_estimate([s]).bottleneck == "compute":
            prefill_sat = s
            break
    return {"prefill_compute_saturation_tokens": prefill_sat,
            "decode_bs_sat": pm.compute_saturated_batch(1024)}
