"""Table 5 / Figure 1 reproduction: synthesized trace statistics and the
§5.1.3 scaling invariants (pattern preservation)."""
from __future__ import annotations

from repro.data import traces as tr


def run_traces(duration=600.0, seed=0):
    rows = {}
    for ds, key in [("ooc", "ooc_online"), ("azure_conv", "azure_conv"),
                    ("azure_code", "azure_code")]:
        t = tr.online_trace(ds, duration=duration, mean_qps=4.0, seed=seed)
        s = tr.trace_stats(t)
        want_p, want_o = tr.DATASET_STATS[key]
        rows[ds] = {**s, "target_prompt": want_p, "target_output": want_o}
    off = tr.offline_requests(5000, seed=seed)
    s = tr.trace_stats(off)
    want_p, want_o = tr.DATASET_STATS["ooc_offline"]
    rows["ooc_offline"] = {**s, "target_prompt": want_p, "target_output": want_o}
    return rows


def run_scaling_invariance(duration=600.0, seed=0):
    """§5.1.3: scaling changes the rate but preserves burst structure."""
    base = tr.online_trace("ooc", duration=duration, mean_qps=4.0, seed=seed)
    s0 = tr.trace_stats(base)
    out = {"base": s0}
    for f in (0.5, 2.0):
        scaled = tr.scale_trace(base, f, seed=seed)
        s = tr.trace_stats(scaled)
        out[f"x{f}"] = {
            **s,
            "rate_ratio": s["mean_qps"] / s0["mean_qps"],
            "burstiness_ratio": s["peak_over_mean"] / s0["peak_over_mean"],
        }
    return out
