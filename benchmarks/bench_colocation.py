"""Figure 6 reproduction: online-offline co-location serving experiment.

Protocol (paper §5.2):
  1. Scale online traffic so the system "just meets" the traffic peak with
     no offline load (highest scale with violation rate <= threshold).
  2. Sweep offline QPS from zero; for each policy, the *maximum effective
     offline throughput* is the highest offline load whose online SLO
     violation rate stays <= 3 %.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.cluster.simulator import SimConfig, Simulator
from repro.configs import get_config
from repro.core.hardware import TPU_V5E
from repro.data import traces as tr

POLICIES = ("base_pd", "online_priority", "ooco")


@dataclass
class ColocationResult:
    dataset: str
    policy: str
    online_scale: float
    max_offline_qps: float
    max_offline_token_tput: float
    violation_at_max: float


def _run(cfg, policy, online, offline_pool, qps, sim_cfg):
    off = tr.with_uniform_qps(offline_pool, qps)
    sim = Simulator(cfg, TPU_V5E, policy, sim_cfg)
    return sim.run(online, off)


def calibrate_online_scale(cfg, dataset, sim_cfg, *, lo=0.5, hi=24.0,
                           iters=6, seed=0):
    """Highest online mean QPS with violations <= threshold at zero offline."""
    thr = sim_cfg.violation_threshold
    for _ in range(iters):
        mid = (lo + hi) / 2
        online = tr.online_trace(dataset, duration=sim_cfg.duration,
                                 mean_qps=mid, seed=seed)
        m = _run(cfg, "base_pd", online, [], 0.0, sim_cfg)
        if m["online_violation_rate"] <= thr:
            lo = mid
        else:
            hi = mid
    return lo


def max_offline_throughput(cfg, policy, online, offline_pool, sim_cfg,
                           qps_ladder):
    """Largest offline load on the ladder keeping online violations <= 3 %."""
    best_qps, best_tput, best_viol = 0.0, 0.0, 0.0
    rows = []
    for qps in qps_ladder:
        m = _run(cfg, policy, online, offline_pool, qps, sim_cfg)
        rows.append((qps, m))
        if m["online_violation_rate"] <= sim_cfg.violation_threshold:
            if m["offline_token_throughput"] >= best_tput:
                best_qps = qps
                best_tput = m["offline_token_throughput"]
                best_viol = m["online_violation_rate"]
        else:
            break  # violations rise monotonically with offline load
    return best_qps, best_tput, best_viol, rows


def run_colocation(arch="qwen2.5-7b", datasets=("ooc", "azure_conv", "azure_code"),
                   duration=180.0, tp=4, seed=0, verbose=True):
    """One Fig.-6 panel row per dataset for `arch` (paper: Qwen2.5 7B on one
    chip and 72B on a TP-4 instance; our v5e instances are TP-4 for the 7B
    and TP-16 for the 72B to fit 16 GB/chip)."""
    cfg = get_config(arch)
    sim_cfg = SimConfig(duration=duration, tp=tp, seed=seed)
    results: list[ColocationResult] = []
    offline_pool = tr.offline_requests(30000, seed=seed + 1)
    for ds in datasets:
        scale = calibrate_online_scale(cfg, ds, sim_cfg, seed=seed)
        online = tr.online_trace(ds, duration=duration, mean_qps=scale, seed=seed)
        ladder = [2, 4, 8, 12, 16, 24, 32, 48, 64]
        for policy in POLICIES:
            qps, tput, viol, rows = max_offline_throughput(
                cfg, policy, online, offline_pool, sim_cfg, ladder)
            results.append(ColocationResult(f"{arch}/{ds}", policy, scale,
                                            qps, tput, viol))
            if verbose:
                for q, m in rows:
                    print(f"  {arch}/{ds:12s} {policy:16s} offQPS={q:5.1f} "
                          f"viol={m['online_violation_rate']:.3f} "
                          f"tok/s={m['offline_token_throughput']:8.1f}", flush=True)
    return results


def summarize(results):
    lines = []
    by_ds: dict[str, dict[str, ColocationResult]] = {}
    for r in results:
        by_ds.setdefault(r.dataset, {})[r.policy] = r
    for ds, pr in by_ds.items():
        best_base = max(pr["base_pd"].max_offline_token_tput,
                        pr["online_priority"].max_offline_token_tput)
        ooco = pr["ooco"].max_offline_token_tput
        ratio = ooco / best_base if best_base else float("inf")
        lines.append((ds, {p: r.max_offline_token_tput for p, r in pr.items()},
                      ratio))
    return lines
