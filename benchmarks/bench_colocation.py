"""Figure 6 reproduction: online-offline co-location serving experiment.

Two layers:

* **Simulator sweep** (paper §5.2 protocol): scale online traffic to the
  peak, sweep offline QPS, report each policy's maximum effective offline
  throughput at <= 3 % online violations.
* **Real-runtime policy comparison** (``run_runtime_policy_comparison``):
  the pool runtime replays one bursty trace per policy under the virtual
  clock — real JAX engines, deterministic modeled time — and records the
  ``base_pd`` / ``online_priority`` / ``ooco`` summaries in
  ``BENCH_colocation.json``. This is the regression gate the
  ``colocation-replay`` CI step runs (``--quick``).
"""
from __future__ import annotations

import argparse
import json
import time
from dataclasses import dataclass

from repro.cluster.runtime import PoolRuntime, VirtualClock, replay_hw
from repro.cluster.simulator import SimConfig, Simulator
from repro.configs import get_config
from repro.core.hardware import TPU_V5E
from repro.data import traces as tr

POLICIES = ("base_pd", "online_priority", "ooco")


@dataclass
class ColocationResult:
    dataset: str
    policy: str
    online_scale: float
    max_offline_qps: float
    max_offline_token_tput: float
    violation_at_max: float


def _run(cfg, policy, online, offline_pool, qps, sim_cfg):
    off = tr.with_uniform_qps(offline_pool, qps)
    sim = Simulator(cfg, TPU_V5E, policy, sim_cfg)
    return sim.run(online, off)


def calibrate_online_scale(cfg, dataset, sim_cfg, *, lo=0.5, hi=24.0,
                           iters=6, seed=0):
    """Highest online mean QPS with violations <= threshold at zero offline."""
    thr = sim_cfg.violation_threshold
    for _ in range(iters):
        mid = (lo + hi) / 2
        online = tr.online_trace(dataset, duration=sim_cfg.duration,
                                 mean_qps=mid, seed=seed)
        m = _run(cfg, "base_pd", online, [], 0.0, sim_cfg)
        if m["online_violation_rate"] <= thr:
            lo = mid
        else:
            hi = mid
    return lo


def max_offline_throughput(cfg, policy, online, offline_pool, sim_cfg,
                           qps_ladder):
    """Largest offline load on the ladder keeping online violations <= 3 %."""
    best_qps, best_tput, best_viol = 0.0, 0.0, 0.0
    rows = []
    for qps in qps_ladder:
        m = _run(cfg, policy, online, offline_pool, qps, sim_cfg)
        rows.append((qps, m))
        if m["online_violation_rate"] <= sim_cfg.violation_threshold:
            if m["offline_token_throughput"] >= best_tput:
                best_qps = qps
                best_tput = m["offline_token_throughput"]
                best_viol = m["online_violation_rate"]
        else:
            break  # violations rise monotonically with offline load
    return best_qps, best_tput, best_viol, rows


def run_colocation(arch="qwen2.5-7b", datasets=("ooc", "azure_conv", "azure_code"),
                   duration=180.0, tp=4, seed=0, verbose=True):
    """One Fig.-6 panel row per dataset for `arch` (paper: Qwen2.5 7B on one
    chip and 72B on a TP-4 instance; our v5e instances are TP-4 for the 7B
    and TP-16 for the 72B to fit 16 GB/chip)."""
    cfg = get_config(arch)
    sim_cfg = SimConfig(duration=duration, tp=tp, seed=seed)
    results: list[ColocationResult] = []
    offline_pool = tr.offline_requests(30000, seed=seed + 1)
    for ds in datasets:
        scale = calibrate_online_scale(cfg, ds, sim_cfg, seed=seed)
        online = tr.online_trace(ds, duration=duration, mean_qps=scale, seed=seed)
        ladder = [2, 4, 8, 12, 16, 24, 32, 48, 64]
        for policy in POLICIES:
            qps, tput, viol, rows = max_offline_throughput(
                cfg, policy, online, offline_pool, sim_cfg, ladder)
            results.append(ColocationResult(f"{arch}/{ds}", policy, scale,
                                            qps, tput, viol))
            if verbose:
                for q, m in rows:
                    print(f"  {arch}/{ds:12s} {policy:16s} offQPS={q:5.1f} "
                          f"viol={m['online_violation_rate']:.3f} "
                          f"tok/s={m['offline_token_throughput']:8.1f}", flush=True)
    return results


def run_runtime_policy_comparison(*, arch="qwen2.5-7b", duration=10.0,
                                  online_qps=1.2, n_offline=100,
                                  offline_qps=20.0, n_strict=1, n_relaxed=2,
                                  slo_ttft=1.0, slo_tpot=0.030, seed=0,
                                  chunk_tokens="auto", decode_horizon="auto",
                                  quick=False, verbose=True):
    """Replay one bursty trace per policy through the REAL pool runtime
    under the virtual clock. Deterministic: the same seed reproduces the
    same summaries bit-for-bit, so policy regressions diff cleanly.

    Fixed evaluation window (§5.2 protocol): the offline backlog saturates
    the cluster, every policy gets the same window (no drain), and offline
    tokens/s measures what the policy extracted at its SLO attainment —
    a lighter trace lets every policy finish everything and the
    throughputs tie."""
    import jax

    from repro.models.model import build_model

    if quick:
        duration, n_offline = 6.0, 60
    cfg = get_config(arch).reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(seed))
    online = tr.online_trace("ooc", duration=duration, mean_qps=online_qps,
                             seed=seed)
    offline = tr.with_uniform_qps(
        tr.offline_requests(n_offline, seed=seed + 1), offline_qps)
    donor = None
    out = {}
    for policy in POLICIES:
        rt = PoolRuntime(cfg, policy=policy, n_strict=n_strict,
                         n_relaxed=n_relaxed, clock=VirtualClock(),
                         backend="ref", num_pages=256, page_size=8,
                         slo_ttft=slo_ttft, slo_tpot=slo_tpot,
                         hw=replay_hw(), seed=seed, model=model,
                         params=params, chunk_tokens=chunk_tokens,
                         decode_horizon=decode_horizon, kernels_from=donor)
        donor = donor or rt.kernel_donor
        t0 = time.perf_counter()
        m = rt.run(online, offline, duration=duration, max_prompt=48,
                   max_output=12, drain=False)
        m["wall_seconds"] = round(time.perf_counter() - t0, 2)
        out[policy] = m
        if verbose:
            print(f"  runtime {policy:16s} attain={m['online_slo_attainment']:.2f} "
                  f"tpot_p99={m['online_tpot_p99']:.4f} "
                  f"offline_tok/s={m['offline_tokens_per_s']:.1f} "
                  f"pulls={m['pulls']} preemptions={m['preemptions']}",
                  flush=True)
    return {
        "arch": arch,
        "topology": f"{n_strict}-strict+{n_relaxed}-relaxed",
        "slo_ttft": slo_ttft,
        "slo_tpot": slo_tpot,
        "chunk_tokens": chunk_tokens,
        "decode_horizon": decode_horizon,
        "duration": duration,
        "policies": out,
        "ooco_vs_online_priority_offline_tput": round(
            out["ooco"]["offline_tokens_per_s"]
            / max(out["online_priority"]["offline_tokens_per_s"], 1e-9), 3),
    }


def run_datacenter_replay(*, arch="qwen2.5-7b", duration=10.0,
                          online_qps=8.0, n_offline=1000, offline_qps=150.0,
                          max_output=48, n_strict=1, n_relaxed=2,
                          slo_ttft=2.0, slo_tpot=0.06, seed=0, quick=False,
                          verbose=True):
    """Datacenter-overhead replay: the same bursty trace under
    ``replay_hw('v5e')`` — the virtual clock charges the REAL TPU v5e
    per-dispatch overheads (O_p=8 ms, O_d=4 ms) against uniformly scaled
    compute rates, i.e. the overhead:work ratio of a datacenter
    accelerator, where amortizing dispatches across multi-step horizons
    and fused mixed horizons actually pays. Three runs: the
    ``online_priority`` baseline, ``ooco`` with horizons forced off
    (``decode_horizon=1`` — every relaxed round syncs per token), and
    full ``ooco`` (auto horizons + fused mixed horizons).

    Acceptance: full ooco keeps >= online_priority offline tokens/s at
    100 % online SLO attainment, and fires fused mixed-horizon rounds
    (``mixed_horizon_rounds > 0``) that its horizon-1 variant cannot."""
    import jax

    from repro.models.model import build_model

    if quick:
        duration, n_offline = 6.0, 600
    cfg = get_config(arch).reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(seed))
    online = tr.online_trace("ooc", duration=duration, mean_qps=online_qps,
                             seed=seed)
    offline = tr.with_uniform_qps(
        tr.offline_requests(n_offline, seed=seed + 1), offline_qps)
    hw = replay_hw("v5e")
    variants = (("online_priority", "online_priority", "auto"),
                ("ooco_h1", "ooco", 1),
                ("ooco", "ooco", "auto"))
    donor, out = None, {}
    for name, policy, horizon in variants:
        rt = PoolRuntime(cfg, policy=policy, n_strict=n_strict,
                         n_relaxed=n_relaxed, clock=VirtualClock(),
                         backend="ref", num_pages=256, page_size=8,
                         slo_ttft=slo_ttft, slo_tpot=slo_tpot, hw=hw,
                         seed=seed, model=model, params=params,
                         chunk_tokens="auto", decode_horizon=horizon,
                         kernels_from=donor)
        donor = donor or rt.kernel_donor
        t0 = time.perf_counter()
        m = rt.run(online, offline, duration=duration, max_prompt=48,
                   max_output=max_output, drain=False)
        m["wall_seconds"] = round(time.perf_counter() - t0, 2)
        out[name] = m
        if verbose:
            print(f"  datacenter {name:16s} attain="
                  f"{m['online_slo_attainment']:.2f} "
                  f"tpot_p99={m['online_tpot_p99']:.4f} "
                  f"offline_tok/s={m['offline_tokens_per_s']:.1f} "
                  f"horizon_rounds={m['horizon_rounds']} "
                  f"mixed_horizon_rounds={m['mixed_horizon_rounds']}",
                  flush=True)
    return {
        "arch": arch,
        "hw": hw.name,
        "topology": f"{n_strict}-strict+{n_relaxed}-relaxed",
        "slo_ttft": slo_ttft,
        "slo_tpot": slo_tpot,
        "duration": duration,
        "policies": out,
        "ooco_vs_online_priority_offline_tput": round(
            out["ooco"]["offline_tokens_per_s"]
            / max(out["online_priority"]["offline_tokens_per_s"], 1e-9), 3),
        "ooco_vs_horizon1_offline_tput": round(
            out["ooco"]["offline_tokens_per_s"]
            / max(out["ooco_h1"]["offline_tokens_per_s"], 1e-9), 3),
        "mixed_horizon_rounds": out["ooco"]["mixed_horizon_rounds"],
    }


def run_chaos_replay(*, arch="qwen2.5-7b", duration=10.0, online_qps=1.2,
                     n_offline=100, offline_qps=20.0, n_strict=1,
                     n_relaxed=2, slo_ttft=1.0, slo_tpot=0.030, seed=0,
                     chaos_seed=7, fault_plan=None, quick=False,
                     verbose=True):
    """Graceful-degradation gate (ISSUE 6): replay the policy-comparison
    trace through ``ooco`` twice — fault-free, then with one relaxed
    engine crashed mid-trace — and report the offline throughput loss.

    Acceptance: the crashed run still attains 100 % online SLO (online
    traffic never loses its pool; crashed offline work re-admits through
    the recompute path) and the loss is *reported*, never silent. Both
    runs are virtual-clock deterministic, so this doubles as a regression
    gate on the recovery path itself."""
    import jax

    from repro.models.model import build_model

    if quick:
        duration, n_offline = 6.0, 60
    if fault_plan is None:
        fault_plan = f"crash:relaxed{n_relaxed - 1}@{duration / 2}"
    cfg = get_config(arch).reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(seed))
    online = tr.online_trace("ooc", duration=duration, mean_qps=online_qps,
                             seed=seed)
    offline = tr.with_uniform_qps(
        tr.offline_requests(n_offline, seed=seed + 1), offline_qps)
    donor = None
    runs = {}
    for name, plan in (("clean", None), ("chaos", fault_plan)):
        rt = PoolRuntime(cfg, policy="ooco", n_strict=n_strict,
                         n_relaxed=n_relaxed, clock=VirtualClock(),
                         backend="ref", num_pages=256, page_size=8,
                         slo_ttft=slo_ttft, slo_tpot=slo_tpot,
                         hw=replay_hw(), seed=seed, model=model,
                         params=params, fault_plan=plan,
                         chaos_seed=chaos_seed, kernels_from=donor)
        donor = donor or rt.kernel_donor
        t0 = time.perf_counter()
        m = rt.run(online, offline, duration=duration, max_prompt=48,
                   max_output=12, drain=False)
        m["wall_seconds"] = round(time.perf_counter() - t0, 2)
        runs[name] = m
        if verbose:
            print(f"  chaos-replay {name:6s} attain="
                  f"{m['online_slo_attainment']:.2f} "
                  f"offline_tok/s={m['offline_tokens_per_s']:.1f} "
                  f"crashes={m['engine_crashes']} "
                  f"recoveries={m['recoveries']} "
                  f"recompute={m['recompute_tokens']}", flush=True)
    loss = 1.0 - (runs["chaos"]["offline_tokens_per_s"]
                  / max(runs["clean"]["offline_tokens_per_s"], 1e-9))
    return {
        "arch": arch,
        "topology": f"{n_strict}-strict+{n_relaxed}-relaxed",
        "fault_plan": fault_plan,
        "chaos_seed": chaos_seed,
        "duration": duration,
        "runs": runs,
        "offline_tput_loss": round(loss, 3),
    }


def run_prefix_reuse(*, arch="qwen2.5-7b", num_prefixes=2, variants=2,
                     queries=16, prefix_tokens=112, variant_tokens=8,
                     query_tokens=8, output_len=3, offline_qps=8.0,
                     num_pages=512, duration=60.0, seed=0, quick=False,
                     verbose=True):
    """Cross-request KV reuse (ISSUE 7): replay the seeded shared-prefix
    trace (P system prompts x Q few-shot variants x R queries) through the
    pool runtime twice — radix prefix cache on, then off — under the
    virtual clock.

    Acceptance: the two runs' finished token streams are BIT-IDENTICAL
    (asserted request-by-request: a cache hit replays pages whose KV bits
    match what cold prefill would compute), and effective prefill
    throughput (prompt tokens admitted / modeled prefill compute seconds)
    improves >= 5x with the cache on (CI floor: 3x)."""
    import jax

    from repro.models.model import build_model

    if quick:
        queries = 8
    cfg = get_config(arch).reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(seed))
    offline = tr.with_uniform_qps(
        tr.shared_prefix_requests(
            num_prefixes=num_prefixes, variants=variants, queries=queries,
            prefix_tokens=prefix_tokens, variant_tokens=variant_tokens,
            query_tokens=query_tokens, output_len=output_len,
            vocab=cfg.vocab_size, seed=seed + 1),
        offline_qps)
    donor, runs, sigs = None, {}, {}
    for name, pc in (("cache_on", True), ("cache_off", False)):
        rt = PoolRuntime(cfg, policy="ooco", n_strict=1, n_relaxed=1,
                         clock=VirtualClock(), backend="ref",
                         hw=replay_hw(), num_pages=num_pages, seed=seed,
                         model=model, params=params, chunk_tokens="auto",
                         prefix_cache=pc, kernels_from=donor)
        donor = donor or rt.kernel_donor
        t0 = time.perf_counter()
        m = rt.run([], offline, duration=duration,
                   max_prompt=prefix_tokens + variant_tokens + query_tokens,
                   max_output=output_len + 1)
        m["wall_seconds"] = round(time.perf_counter() - t0, 2)
        m["effective_prefill_tokens_per_s"] = round(
            m["prefill_tokens"] / max(m["prefill_modeled_seconds"], 1e-12), 1)
        runs[name] = m
        sigs[name] = rt.finished_signature()
        if verbose:
            print(f"  prefix-reuse {name:9s} "
                  f"eff_prefill={m['effective_prefill_tokens_per_s']:.0f}tok/s "
                  f"hits={m['prefix_hits']}/{m['offline_requests']} "
                  f"cached={m['cached_tokens']}/{m['prefill_tokens']}tok "
                  f"shared_pages={m['shared_pages']}", flush=True)
    # the correctness bar: greedy streams must be bit-identical per request
    token_parity = sigs["cache_on"] == sigs["cache_off"]
    assert token_parity, \
        "prefix cache changed the token streams — KV reuse is NOT exact"
    on, off = runs["cache_on"], runs["cache_off"]
    speedup = (on["effective_prefill_tokens_per_s"]
               / max(off["effective_prefill_tokens_per_s"], 1e-9))
    return {
        "arch": arch,
        "trace": {"num_prefixes": num_prefixes, "variants": variants,
                  "queries": queries, "prefix_tokens": prefix_tokens,
                  "variant_tokens": variant_tokens,
                  "query_tokens": query_tokens, "seed": seed + 1},
        "runs": runs,
        "token_parity": token_parity,
        "hit_rate": round(on["prefix_hits"]
                          / max(on["offline_requests"], 1), 3),
        "cached_token_fraction": round(
            on["cached_tokens"] / max(on["prefill_tokens"], 1), 3),
        "effective_prefill_speedup": round(speedup, 2),
    }


def write_bench_json(result, chaos=None, prefix_reuse=None, datacenter=None,
                     path="BENCH_colocation.json"):
    blob = {
        "bench": "colocation",
        "description": (
            "Real pool-runtime policy comparison: one bursty synthetic trace "
            "(ooc stats) replayed per policy through PoolRuntime under the "
            "virtual clock (real JAX engines, perf-model time — "
            "deterministic), with chunked prefill enabled (fused mixed "
            "steps, roofline-guided auto token budgets, §3.4.1 preemption "
            "at chunk boundaries) and multi-step decode horizons on "
            "(roofline-chosen K on chunkless latency-relaxed rounds, one "
            "dispatch overhead charged per horizon; push-migration KV "
            "transfers overlap the source round's compute). Acceptance: "
            "ooco offline tokens/s > "
            "online_priority at equal-or-better online SLO attainment; "
            "base_pd violates the TPOT SLO; and (chaos_replay) with one "
            "relaxed engine crashed mid-trace via deterministic fault "
            "injection, ooco still attains 100% online SLO with the "
            "offline throughput loss reported; and (prefix_reuse) on the "
            "seeded shared-prefix trace the radix prefix cache improves "
            "effective prefill throughput >=5x (CI floor 3x) with "
            "bit-exact greedy token parity vs cold prefill. Reproduce: "
            "PYTHONPATH=src python benchmarks/bench_colocation.py "
            "[--quick]."),
        "runtime_policy_comparison": result,
    }
    if chaos is not None:
        blob["chaos_replay"] = chaos
    if prefix_reuse is not None:
        blob["prefix_reuse"] = prefix_reuse
    if datacenter is not None:
        blob["datacenter_replay"] = datacenter
    with open(path, "w") as f:
        json.dump(blob, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default="BENCH_colocation.json",
                    help="path for the policy-comparison record "
                         "('' disables writing)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    res = run_runtime_policy_comparison(quick=args.quick, seed=args.seed)
    pol = res["policies"]
    ooco, op, base = pol["ooco"], pol["online_priority"], pol["base_pd"]
    ok = (ooco["offline_tokens_per_s"] > op["offline_tokens_per_s"]
          and ooco["online_slo_attainment"] >= op["online_slo_attainment"]
          and ooco["online_slo_attainment"] >= base["online_slo_attainment"])
    chaos = run_chaos_replay(quick=args.quick, seed=args.seed)
    chaos_ok = (chaos["runs"]["chaos"]["online_slo_attainment"] >= 1.0
                and chaos["runs"]["chaos"]["engine_crashes"] == 1)
    reuse = run_prefix_reuse(quick=args.quick, seed=args.seed)
    reuse_ok = (reuse["token_parity"]
                and reuse["effective_prefill_speedup"] >= 3.0)
    dc = run_datacenter_replay(quick=args.quick, seed=args.seed)
    dc_ok = (dc["policies"]["ooco"]["online_slo_attainment"] >= 1.0
             and dc["ooco_vs_online_priority_offline_tput"] >= 1.0
             and dc["mixed_horizon_rounds"] > 0)
    ok = ok and chaos_ok and reuse_ok and dc_ok
    print(f"ooco_vs_online_priority={res['ooco_vs_online_priority_offline_tput']}x "
          f"chaos_offline_tput_loss={chaos['offline_tput_loss']} "
          f"prefix_reuse_speedup={reuse['effective_prefill_speedup']}x "
          f"datacenter_ooco_vs_op={dc['ooco_vs_online_priority_offline_tput']}x "
          f"(vs_h1={dc['ooco_vs_horizon1_offline_tput']}x) "
          f"acceptance={'PASS' if ok else 'FAIL'}")
    if args.json:
        print(f"wrote {write_bench_json(res, chaos, reuse, dc, args.json)}")
    return 0 if ok else 1


def summarize(results):
    lines = []
    by_ds: dict[str, dict[str, ColocationResult]] = {}
    for r in results:
        by_ds.setdefault(r.dataset, {})[r.policy] = r
    for ds, pr in by_ds.items():
        best_base = max(pr["base_pd"].max_offline_token_tput,
                        pr["online_priority"].max_offline_token_tput)
        ooco = pr["ooco"].max_offline_token_tput
        ratio = ooco / best_base if best_base else float("inf")
        lines.append((ds, {p: r.max_offline_token_tput for p, r in pr.items()},
                      ratio))
    return lines


if __name__ == "__main__":
    import sys
    sys.exit(main())
