"""Roofline table generator: reads the dry-run JSON artifacts and renders
the per-(arch x shape x mesh) roofline terms for EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import json


def load(path: str) -> list[dict]:
    with open(path) as f:
        return json.load(f)


def _fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.0f}us"


def roofline_table(results: list[dict]) -> str:
    head = ("| arch | shape | mesh | compute | memory | collective | dominant "
            "| MODEL_FLOPS/HLO | temp/dev | note |")
    sep = "|" + "---|" * 10
    lines = [head, sep]
    for r in results:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - "
                         f"| - | - | - | - | SKIP: {r['skipped']} |")
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - "
                         f"| - | - | - | - | ERROR |")
            continue
        rf = r["roofline"]
        uf = rf.get("useful_fraction")
        temp = (r["memory"]["temp_bytes"] or 0) / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {_fmt_s(rf['compute_s'])} | {_fmt_s(rf['memory_s'])} "
            f"| {_fmt_s(rf['collective_s'])} | {rf['dominant']} "
            f"| {uf:.2f} | {temp:.2f}GB | |" if uf is not None else
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - | - | - | - "
            f"| {temp:.2f}GB | |")
    return "\n".join(lines)


def dominant_summary(results: list[dict]) -> dict:
    out = {"compute": [], "memory": [], "collective": []}
    for r in results:
        if "roofline" in r:
            out[r["roofline"]["dominant"]].append(
                (r["arch"], r["shape"],
                 max(r["roofline"]["compute_s"], r["roofline"]["memory_s"],
                     r["roofline"]["collective_s"])))
    return out


def worst_cases(results: list[dict], n=5):
    """Cases with the worst roofline fraction (dominant >> others) and the
    most collective-bound — hillclimb candidates."""
    rows = []
    for r in results:
        if "roofline" not in r:
            continue
        rf = r["roofline"]
        terms = sorted([rf["compute_s"], rf["memory_s"], rf["collective_s"]],
                       reverse=True)
        imbalance = terms[0] / max(terms[1], 1e-12)
        rows.append((imbalance, rf["dominant"], r["arch"], r["shape"]))
    rows.sort(reverse=True)
    return rows[:n]


if __name__ == "__main__":
    import sys
    res = load(sys.argv[1] if len(sys.argv) > 1 else "dryrun_single_pod.json")
    print(roofline_table(res))
    print()
    for imb, dom, arch, shape in worst_cases(res, 8):
        print(f"imbalance {imb:7.1f}x  {dom:10s} {arch} x {shape}")
