"""Beyond-paper experiment: pool-size ratios.

The paper evaluates 1 latency-relaxed + 1 latency-strict instance (§5.1.1).
Production clusters choose a ratio; OOCO's flexible offline-decode placement
should make throughput *less sensitive* to that ratio than the baselines
(its offline decode soaks up whichever pool has slack). We sweep
(n_relaxed, n_strict) at fixed total instances and measure the max offline
throughput under the online SLO.
"""
from __future__ import annotations

from repro.cluster.simulator import SimConfig, Simulator
from repro.configs import get_config
from repro.core.hardware import TPU_V5E
from repro.data import traces as tr


def run_pool_ratio(arch="qwen2.5-7b", total=4, duration=150.0, tp=4,
                   online_qps=18.0, offline_qps=32.0, seed=0, verbose=True):
    cfg = get_config(arch)
    online = tr.online_trace("ooc", duration=duration, mean_qps=online_qps,
                             seed=seed)
    pool = tr.offline_requests(30000, seed=seed + 1)
    rows = []
    for n_relaxed in range(1, total):
        n_strict = total - n_relaxed
        for policy in ("online_priority", "ooco"):
            sim = Simulator(cfg, TPU_V5E, policy,
                            SimConfig(duration=duration, tp=tp,
                                      n_relaxed=n_relaxed, n_strict=n_strict,
                                      seed=seed))
            m = sim.run(online, tr.with_uniform_qps(pool, offline_qps))
            rows.append({"relaxed": n_relaxed, "strict": n_strict,
                         "policy": policy,
                         "viol": m["online_violation_rate"],
                         "off_tok_s": m["offline_token_throughput"]})
            if verbose:
                print(f"  P{n_relaxed}:D{n_strict} {policy:16s} "
                      f"viol={m['online_violation_rate']:.3f} "
                      f"off={m['offline_token_throughput']:8.1f} tok/s",
                      flush=True)
    return rows


def sensitivity(rows) -> dict:
    """max/min offline throughput across SLO-feasible ratios, per policy."""
    out = {}
    for policy in ("online_priority", "ooco"):
        ok = [r["off_tok_s"] for r in rows
              if r["policy"] == policy and r["viol"] <= 0.03]
        if ok:
            out[policy] = {"best": max(ok), "worst": min(ok),
                           "sensitivity": max(ok) / max(min(ok), 1e-9)}
    return out
