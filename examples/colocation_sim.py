"""Cluster-scale co-location experiment (Figure 6) via the discrete-event
simulator: sweep offline load under the three policies and report the max
offline throughput each sustains within the online SLO.

  PYTHONPATH=src python examples/colocation_sim.py [--duration 120]
"""
import argparse

from repro.cluster.simulator import SimConfig, Simulator
from repro.configs import get_config
from repro.core.hardware import TPU_V5E
from repro.data import traces as tr


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-7b")
    ap.add_argument("--dataset", default="ooc",
                    choices=["ooc", "azure_conv", "azure_code"])
    ap.add_argument("--duration", type=float, default=120.0)
    ap.add_argument("--online-qps", type=float, default=6.0)
    ap.add_argument("--tp", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    online = tr.online_trace(args.dataset, duration=args.duration,
                             mean_qps=args.online_qps, seed=0)
    pool = tr.offline_requests(20000, seed=1)
    print(f"{args.dataset}: {len(online)} online requests over "
          f"{args.duration:.0f}s (mean {args.online_qps}/s)")
    print(f"{'policy':16s} {'offQPS':>6s} {'viol%':>6s} {'off tok/s':>10s} "
          f"{'p99 TTFT':>9s} {'p50 TPOT':>9s}")
    for policy in ("base_pd", "online_priority", "ooco"):
        for qps in (4.0, 12.0, 32.0):
            off = tr.with_uniform_qps(pool, qps)
            sim = Simulator(cfg, TPU_V5E, policy,
                            SimConfig(duration=args.duration, tp=args.tp))
            m = sim.run(online, off)
            print(f"{policy:16s} {qps:6.1f} "
                  f"{m['online_violation_rate']*100:6.1f} "
                  f"{m['offline_token_throughput']:10.1f} "
                  f"{m['online_p99_ttft']:8.2f}s "
                  f"{m['online_p50_tpot']*1e3:7.1f}ms")


if __name__ == "__main__":
    main()
