"""Live gateway walkthrough: async streams, cancellation, deadlines,
backpressure, health, and a leak-free drain — on real JAX compute.

A compact tour of the PR 9 serving surface (``cluster.gateway``):

1. concurrent clients stream tokens as the pools produce them;
2. one client disconnects mid-stream (its KV pages are freed instantly);
3. one request carries a total deadline tight enough to blow (the runtime
   aborts it and bills the SLO violation — it never goes silent);
4. a burst overflows the bounded online queue (``AdmissionRejected``);
5. a health probe reports engine-slot liveness and queue depths;
6. a graceful drain finishes in-flight work and proves zero live pages.

  PYTHONPATH=src python examples/serve_gateway.py
  PYTHONPATH=src python examples/serve_gateway.py --clients 12 --relaxed 2
"""
import argparse
import asyncio

from repro.cluster.gateway import AdmissionRejected, Gateway
from repro.cluster.runtime import PoolRuntime, WallClock
from repro.configs import get_config
from repro.core.request import Kind


async def demo(args) -> int:
    cfg = get_config(args.arch).reduced()
    print(f"building {args.strict} strict + {args.relaxed} relaxed "
          f"engines (reduced {args.arch}) ...")
    runtime = PoolRuntime(cfg, policy="ooco", n_strict=args.strict,
                          n_relaxed=args.relaxed, clock=WallClock(),
                          slo_ttft=30.0, slo_tpot=1.0, num_pages=256,
                          page_size=8, backend=args.backend,
                          max_online_queue=args.max_online_queue)
    gateway = Gateway(runtime)
    await gateway.start()

    async def client(i: int) -> str:
        kw = {}
        role = "plain"
        if i == 0:
            role = "disconnect"
        elif i == 1:
            role, kw["total_deadline"] = "tight-deadline", 0.001
        elif i == 2:
            role, kw["kind"] = "offline", Kind.OFFLINE
        try:
            stream = await gateway.submit(
                [i * 7 + t for t in range(1, 9)],
                max_new_tokens=args.tokens, **kw)
        except AdmissionRejected:
            print(f"  client {i:2d} [{role}] -> rejected (backpressure)")
            return "rejected"
        toks = []
        async for tok in stream:
            toks.append(tok)
            if role == "disconnect" and len(toks) >= 2:
                await stream.cancel()
                break
        print(f"  client {i:2d} [{role}] -> {stream.outcome or 'cancelled'} "
              f"after {len(toks)} tokens")
        return stream.outcome or "cancelled"

    outcomes = await asyncio.gather(
        *(client(i) for i in range(args.clients)))

    health = gateway.health()
    print(f"health: status={health['status']} "
          f"engines={[(e['name'], 'up' if e['alive'] else 'down') for e in health['engines']]} "
          f"queued={health['queued_online']}+{health['queued_offline']}")

    report = await gateway.drain(timeout=60.0)
    leaked = sum(report["leaked_pages"].values())
    s = report["summary"]
    print(f"drained: finished={s['online_finished'] + s['offline_finished']} "
          f"cancelled={s['cancelled']} deadline_aborts={s['deadline_aborts']} "
          f"rejected={s['rejected_online']}")
    print(f"leaked pages after drain: {report['leaked_pages']} "
          f"({'LEAK!' if leaked else 'clean'})")
    assert sorted(set(outcomes)) and leaked == 0
    return 1 if leaked else 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-7b")
    ap.add_argument("--backend", default="ref",
                    choices=["auto", "pallas", "interpret", "ref"])
    ap.add_argument("--strict", type=int, default=1)
    ap.add_argument("--relaxed", type=int, default=1)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--max-online-queue", type=int, default=64)
    args = ap.parse_args()
    return asyncio.run(demo(args))


if __name__ == "__main__":
    raise SystemExit(main())
