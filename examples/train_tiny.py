"""Train a ~100M-param model for a few hundred steps on the synthetic corpus
(end-to-end training driver; the serving paper still ships a real train path
for the assigned train_4k workload shape).

  PYTHONPATH=src python examples/train_tiny.py --steps 300
"""
import argparse

import jax

from repro.configs import get_config
from repro.models.model import build_model
from repro.training import checkpoint
from repro.training.data_pipeline import DataConfig, packed_batches
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--d-model", type=int, default=384)
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--ckpt", default="/tmp/repro_train_tiny.npz")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(
        layers=args.layers, d_model=args.d_model, vocab=4096, d_ff=1024)
    print(f"{cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"~{cfg.num_params()/1e6:.0f}M params, seq={args.seq} "
          f"batch={args.batch}")
    model = build_model(cfg, remat=True)
    params = model.init(jax.random.PRNGKey(0))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    batch_size=args.batch)
    opt_cfg = AdamWConfig(lr=6e-4, warmup_steps=args.steps // 10,
                          total_steps=args.steps)
    params, opt_state, hist = train(
        model, params, packed_batches(dc, args.steps), opt_cfg,
        log_every=max(args.steps // 15, 1))
    checkpoint.save(args.ckpt, params, opt_state, args.steps)
    first, last = hist[0][1], hist[-1][1]
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"({(first - last) / first:.0%} reduction); checkpoint: {args.ckpt}")


if __name__ == "__main__":
    main()
