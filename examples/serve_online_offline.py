"""End-to-end driver: REAL co-located serving on this host.

Two ServingEngine instances (latency-relaxed + latency-strict) run a reduced
model with actual JAX compute; online requests preempt offline prefills at
transformer-layer granularity, KV migrates between engines, and decode
batches are selected under a measured-TPOT SLO — the full OOCO data path of
Figure 4, executing for real.

  PYTHONPATH=src python examples/serve_online_offline.py --duration 30
"""
import argparse
import time

import numpy as np

from repro.configs import get_config
from repro.core.request import Kind, Request
from repro.data import traces as tr
from repro.launch.serve import CoLocatedServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-7b")
    ap.add_argument("--policy", default="ooco",
                    choices=["base_pd", "online_priority", "ooco"])
    ap.add_argument("--duration", type=float, default=30.0)
    ap.add_argument("--online-qps", type=float, default=0.4)
    ap.add_argument("--offline-qps", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    print(f"serving reduced {args.arch} under policy={args.policy} ...")
    server = CoLocatedServer(cfg, policy=args.policy)
    rng = np.random.default_rng(args.seed)
    online = tr.online_trace("ooc", duration=args.duration,
                             mean_qps=args.online_qps, seed=args.seed)
    n_off = max(int(args.offline_qps * args.duration), 1)
    offline = tr.with_uniform_qps(tr.offline_requests(n_off), args.offline_qps)

    pending = sorted([(t.arrival, Kind.ONLINE, t) for t in online]
                     + [(t.arrival, Kind.OFFLINE, t) for t in offline])
    # warm the jit caches before the clock starts
    server.step()
    t0 = time.perf_counter()
    server.clock = lambda: time.perf_counter() - t0
    # preemption probe: an online request is due the moment its trace
    # timestamp passes (drives real §3.4.1 layer-level interruptions)
    server.incoming_online = lambda: bool(pending) and pending[0][1] == Kind.ONLINE \
        and pending[0][0] <= time.perf_counter() - t0
    while True:
        now = time.perf_counter() - t0
        if now > args.duration and not (
                server.online_queue or server.offline_queue
                or server.strict_online or server.strict_offline
                or server.relaxed_offline):
            break
        if now > 3 * args.duration:
            break  # drain cap
        while pending and pending[0][0] <= now:
            _, kind, t = pending.pop(0)
            p = list(rng.integers(0, cfg.vocab_size, min(max(t.prompt_len, 8), 48)))
            server.submit(Request(kind, now, len(p), min(t.output_len, 24)), p)
        server.step()

    wall = time.perf_counter() - t0
    on = [r for r in server.finished if r.kind == Kind.ONLINE]
    off = [r for r in server.finished if r.kind == Kind.OFFLINE]
    off_tokens = sum(r.generated for r in off)
    ttfts = [r.first_token_time - r.arrival for r in on
             if r.first_token_time is not None]
    print(f"finished: online={len(on)} offline={len(off)} in {wall:.1f}s")
    print(f"offline throughput: {off_tokens / wall:.1f} tok/s "
          f"({off_tokens} tokens)")
    if ttfts:
        print(f"online TTFT p50={np.median(ttfts):.2f}s "
              f"max={max(ttfts):.2f}s")
    print(f"layer-level preemptions: {server.relaxed.stats.preemptions}")
    print(f"strict decode steps: {server.strict.stats.decode_steps}, "
          f"relaxed decode steps: {server.relaxed.stats.decode_steps}")


if __name__ == "__main__":
    main()
