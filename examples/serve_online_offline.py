"""End-to-end driver: REAL pool-based co-located serving on this host.

N latency-strict + M latency-relaxed ServingEngines run a reduced model with
actual JAX compute; online requests preempt offline prefills at transformer-
layer granularity, KV migrates between engine pairs (push after prefill,
§3.4.3 pull when the strict pool has headroom), and decode batches are
selected under the TPOT SLO — the full OOCO data path of Figure 4.

  PYTHONPATH=src python examples/serve_online_offline.py --duration 30
  PYTHONPATH=src python examples/serve_online_offline.py \
      --strict 1 --relaxed 2 --virtual-clock      # deterministic replay
"""
import argparse

from repro.cluster.runtime import (POLICIES, PoolRuntime, VirtualClock,
                                   WallClock, replay_hw)
from repro.configs import get_config
from repro.launch.serve import build_traces


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-7b")
    ap.add_argument("--policy", default="ooco", choices=list(POLICIES))
    ap.add_argument("--strict", type=int, default=1)
    ap.add_argument("--relaxed", type=int, default=1)
    ap.add_argument("--virtual-clock", action="store_true")
    ap.add_argument("--duration", type=float, default=30.0)
    ap.add_argument("--online-qps", type=float, default=0.4)
    ap.add_argument("--offline-qps", type=float, default=1.0)
    ap.add_argument("--trace", default="ooc",
                    choices=["ooc", "shared-prefix"])
    ap.add_argument("--max-prompt", type=int, default=48)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    clock = VirtualClock() if args.virtual_clock else WallClock()
    print(f"serving reduced {args.arch} under policy={args.policy} "
          f"({args.strict} strict + {args.relaxed} relaxed, "
          f"{'virtual' if args.virtual_clock else 'wall'} clock) ...")
    runtime = PoolRuntime(cfg, policy=args.policy, n_strict=args.strict,
                          n_relaxed=args.relaxed, clock=clock,
                          slo_ttft=2.0, slo_tpot=0.05,
                          hw=replay_hw() if args.virtual_clock else None,
                          seed=args.seed)
    online, offline = build_traces(args, cfg)   # same synthesis as the CLI
    m = runtime.run(online, offline, duration=args.duration,
                    max_prompt=args.max_prompt, max_output=24)

    print(f"finished: online={m['online_finished']}/{m['online_requests']} "
          f"offline={m['offline_finished']}/{m['offline_requests']} "
          f"in {m['elapsed']:.1f}s ({m['clock']} time)")
    print(f"offline throughput: {m['offline_tokens_per_s']:.1f} tok/s "
          f"({m['offline_tokens']} tokens)")
    if m["online_ttft_p50"] is not None:
        print(f"online TTFT p50={m['online_ttft_p50']:.3f}s "
              f"p99={m['online_ttft_p99']:.3f}s")
    if m["online_tpot_p50"] is not None:
        print(f"online TPOT p50={m['online_tpot_p50'] * 1e3:.1f}ms "
              f"p99={m['online_tpot_p99'] * 1e3:.1f}ms "
              f"(SLO {runtime.slo_tpot * 1e3:.0f}ms, "
              f"attainment {m['online_slo_attainment']:.0%})")
    print(f"layer-level preemptions: {m['preemptions']}, "
          f"migrations: {m['migrations']} (pulled: {m['pulls']}), "
          f"evictions: {m['evictions']}")
    print(f"rounds: {m['rounds']} (+{m['idle_rounds']} idle skipped)")
    print(f"fused dispatches: decode horizons={m['horizon_rounds']} "
          f"mixed horizons={m['mixed_horizon_rounds']} "
          f"({m['horizon_steps']} horizon steps over "
          f"{m['host_syncs']} host syncs)")
    by_kind = ", ".join(f"{k}={v}" for k, v in
                        sorted(m["dispatches_by_kind"].items()) if v)
    print(f"dispatches by kind: {by_kind or 'none'}")


if __name__ == "__main__":
    main()
