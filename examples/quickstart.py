"""Quickstart: build any assigned architecture, generate a few tokens, and
predict its serving latency with the OOCO roofline perf model.

  PYTHONPATH=src python examples/quickstart.py [--arch qwen3-8b]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED, get_config
from repro.core.hardware import TPU_V5E
from repro.core.perf_model import PerfModel
from repro.models.model import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=ASSIGNED)
    ap.add_argument("--tokens", type=int, default=12)
    args = ap.parse_args()

    full = get_config(args.arch)
    cfg = full.reduced()  # CPU-scale variant of the same family
    print(f"arch={full.name} [{full.family}]  full: {full.num_layers}L "
          f"d={full.d_model} (~{full.num_params()/1e9:.1f}B params) "
          f"| running reduced: {cfg.num_layers}L d={cfg.d_model}")

    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    prompt = list(rng.randint(0, cfg.vocab_size, 16))
    batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
    if cfg.frontend == "vision":
        batch["frontend_embeds"] = jax.random.normal(
            jax.random.PRNGKey(1), (1, cfg.num_frontend_tokens, cfg.d_model),
            jnp.bfloat16)
    if cfg.family == "audio":
        batch["frontend_embeds"] = jax.random.normal(
            jax.random.PRNGKey(1), (1, 64, cfg.d_model), jnp.bfloat16)

    cache_len = len(prompt) + args.tokens + cfg.num_frontend_tokens
    logits, cache = model.prefill(params, batch, cache_len=cache_len)
    out = [int(jnp.argmax(logits, -1)[0])]
    for _ in range(args.tokens - 1):
        logits, cache = model.decode_step(
            params, jnp.asarray([out[-1]], jnp.int32), cache)
        out.append(int(jnp.argmax(logits, -1)[0]))
    print("generated token ids:", out)

    # perf-model view of the FULL-SIZE model on TPU v5e
    pm = PerfModel(full, TPU_V5E, tp=4)
    p = pm.prefill_estimate([1024])
    d = pm.decode_estimate([1024] * 64)
    print(f"v5e(tp=4) predictions: prefill(1024)={p.latency*1e3:.1f}ms "
          f"[{p.bottleneck}]  decode(B=64,ctx=1024)={d.latency*1e3:.1f}ms "
          f"[{d.bottleneck}]  bs_sat={pm.compute_saturated_batch(1024)}")


if __name__ == "__main__":
    main()
