"""Request-trace generation and scaling (paper §5.1.2–5.1.3, Fig. 1, Table 5).

The paper's OOC trace is unreleased and the Azure traces are not available
offline, so we synthesize traces that reproduce the *published statistics*:

* arrival process = tide (hour-scale sinusoid) x bursts (minute-scale
  multiplicative spikes) x Poisson thinning  — the Fig. 1 structure;
* prompt/output lengths: lognormal distributions matched to the Table 5
  means (and CoV ~1, typical of production LLM traces).

``scale_trace`` implements §5.1.3 exactly: rate changes via random dropping
(down) or replication with interpolated timestamps (up), preserving the
temporal fluctuation pattern.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass

import numpy as np

# Table 5: average prompt / output lengths per dataset.
DATASET_STATS = {
    "ooc_online": (1892.47, 1062.62),
    "ooc_offline": (1200.52, 671.51),
    "azure_conv": (1512.30, 98.75),
    "azure_code": (2317.18, 22.74),
}


@dataclass(frozen=True)
class TraceRequest:
    arrival: float
    prompt_len: int
    output_len: int
    # explicit prompt token ids (shared-prefix workloads, where content —
    # not just length — matters for cross-request KV reuse); None = the
    # runtime synthesizes random tokens from its seed as before
    tokens: tuple[int, ...] | None = None


def _lognormal_lengths(rng: np.random.Generator, mean: float, n: int,
                       cov: float = 1.0, lo: int = 4, hi: int = 32768) -> np.ndarray:
    sigma2 = math.log(1.0 + cov ** 2)
    mu = math.log(mean) - sigma2 / 2
    x = rng.lognormal(mu, math.sqrt(sigma2), n)
    return np.clip(x, lo, hi).astype(int)


def _rate_profile(rng: np.random.Generator, duration: float, dt: float,
                  tide_period: float, burst_rate_per_hour: float,
                  burst_mult: tuple[float, float], burst_len: tuple[float, float],
                  ) -> np.ndarray:
    """Multiplicative tide x bursts intensity profile, mean ≈ 1."""
    t = np.arange(0.0, duration, dt)
    tide = 1.0 + 0.6 * np.sin(2 * np.pi * t / tide_period + rng.uniform(0, 2 * np.pi))
    burst = np.ones_like(t)
    n_bursts = rng.poisson(burst_rate_per_hour * duration / 3600.0)
    for _ in range(n_bursts):
        start = rng.uniform(0, duration)
        length = rng.uniform(*burst_len)
        mult = rng.uniform(*burst_mult)
        sel = (t >= start) & (t < start + length)
        burst[sel] = np.maximum(burst[sel], mult)
    prof = tide * burst
    return prof / prof.mean()


def online_trace(dataset: str, *, duration: float = 600.0, mean_qps: float = 2.0,
                 seed: int = 0, tide_period: float = 300.0,
                 burst_rate_per_hour: float = 30.0) -> list[TraceRequest]:
    """Synthesize an online trace with Fig.-1-style fluctuations.

    tide_period defaults to 300 s so a short simulated window still contains
    full tide cycles (a time-compressed version of the hourly pattern)."""
    key = {"ooc": "ooc_online"}.get(dataset, dataset)
    p_mean, o_mean = DATASET_STATS[key]
    rng = np.random.default_rng(seed)
    dt = 1.0
    prof = _rate_profile(rng, duration, dt, tide_period, burst_rate_per_hour,
                         burst_mult=(2.0, 5.0), burst_len=(10.0, 45.0))
    out: list[TraceRequest] = []
    for i, lam in enumerate(prof * mean_qps * dt):
        n = rng.poisson(lam)
        if not n:
            continue
        ts = rng.uniform(i * dt, (i + 1) * dt, n)
        pl = _lognormal_lengths(rng, p_mean, n)
        ol = _lognormal_lengths(rng, o_mean, n, hi=8192)
        out += [TraceRequest(float(a), int(p), int(o)) for a, p, o in zip(ts, pl, ol)]
    out.sort(key=lambda r: r.arrival)
    return out


def offline_requests(n: int, *, seed: int = 1) -> list[TraceRequest]:
    """Offline (batch) jobs with OOC-offline length statistics; arrivals are
    assigned by the QPS controller at evaluation time (§5.2: uniform QPS)."""
    rng = np.random.default_rng(seed)
    pl = _lognormal_lengths(rng, DATASET_STATS["ooc_offline"][0], n)
    ol = _lognormal_lengths(rng, DATASET_STATS["ooc_offline"][1], n, hi=8192)
    return [TraceRequest(0.0, int(p), int(o)) for p, o in zip(pl, ol)]


def shared_prefix_requests(num_prefixes: int = 2, variants: int = 2,
                           queries: int = 4, *, prefix_tokens: int = 48,
                           variant_tokens: int = 16, query_tokens: int = 8,
                           output_len: int = 4, vocab: int = 256,
                           seed: int = 3) -> list[TraceRequest]:
    """Shared-prefix offline workload: ``num_prefixes`` system prompts x
    ``variants`` few-shot variants x ``queries`` user queries (the ConServe/
    sglang analytics shape — prompts share long block-aligned prefixes by
    construction, so a radix prefix cache serves most prefill tokens from
    resident pages). Every request carries EXPLICIT token ids:

      [system prompt | few-shot variant | unique query]

    with the system prompt shared by ``variants * queries`` requests and
    each (prompt, variant) pair shared by ``queries``. Token content is
    drawn deterministically from ``seed``; arrivals are assigned by the QPS
    controller (``with_uniform_qps``) like the other offline generators."""
    rng = np.random.default_rng(seed)
    out: list[TraceRequest] = []
    for p in range(num_prefixes):
        sys_toks = rng.integers(0, vocab, prefix_tokens)
        for v in range(variants):
            var_toks = rng.integers(0, vocab, variant_tokens)
            for q in range(queries):
                qry_toks = rng.integers(0, vocab, query_tokens)
                toks = tuple(int(x) for x in
                             np.concatenate([sys_toks, var_toks, qry_toks]))
                out.append(TraceRequest(0.0, len(toks), output_len,
                                        tokens=toks))
    return out


def with_uniform_qps(reqs: list[TraceRequest], qps: float,
                     start: float = 0.0) -> list[TraceRequest]:
    """Uniform arrival spacing for offline load control (§5.2)."""
    if qps <= 0:
        return []
    return [dataclasses.replace(r, arrival=start + i / qps)
            for i, r in enumerate(reqs)]


def scale_trace(trace: list[TraceRequest], factor: float,
                seed: int = 0) -> list[TraceRequest]:
    """§5.1.3 trace scaling. factor < 1: random dropping; factor > 1:
    replicate lengths, interpolate timestamps. Temporal patterns (burst
    durations, peak/trough ratios) are preserved."""
    rng = np.random.default_rng(seed)
    if factor == 1.0 or not trace:
        return list(trace)
    if factor < 1.0:
        keep = rng.random(len(trace)) < factor
        return [r for r, k in zip(trace, keep) if k]
    out = list(trace)
    extra = int((factor - 1.0) * len(trace))
    idx = rng.integers(0, len(trace) - 1, extra)
    for i in idx:
        a, b = trace[i], trace[min(i + 1, len(trace) - 1)]
        t = rng.uniform(min(a.arrival, b.arrival), max(a.arrival, b.arrival) + 1e-9)
        src = trace[int(rng.integers(0, len(trace)))]  # replicate lengths
        out.append(TraceRequest(float(t), src.prompt_len, src.output_len))
    out.sort(key=lambda r: r.arrival)
    return out


def trace_stats(trace: list[TraceRequest]) -> dict:
    if not trace:
        return {"n": 0}
    pl = np.array([r.prompt_len for r in trace])
    ol = np.array([r.output_len for r in trace])
    ts = np.array([r.arrival for r in trace])
    dur = max(ts.max() - ts.min(), 1e-9)
    # burstiness: peak 10s-window rate over mean rate
    bins = np.histogram(ts, bins=max(int(dur / 10), 1))[0]
    return {
        "n": len(trace),
        "avg_prompt": float(pl.mean()),
        "avg_output": float(ol.mean()),
        "mean_qps": len(trace) / dur,
        "peak_over_mean": float(bins.max() / max(bins.mean(), 1e-9)),
    }
