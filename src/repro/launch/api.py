"""HTTP serving entrypoint over the live gateway (stdlib asyncio only).

A deliberately small HTTP/1.1 layer — no framework dependency — exposing
the gateway's full robustness surface:

* ``POST /v1/generate``  body ``{"prompt": [ids], "kind": "online",
  "max_new_tokens": 16, "ttft_deadline": null, "total_deadline": null}``
  → a newline-delimited JSON stream: first ``{"rid": N}``, then one
  ``{"token": id}`` per generated token, finally ``{"done": outcome}``
  with outcome in finished/cancelled/deadline/error. A client that
  disconnects mid-stream cancels its request server-side (every KV page
  freed); a full online queue answers 429 immediately (backpressure).
* ``GET  /healthz``      → engine-slot liveness, queue depths, and the
  crash/watchdog counters; 200 while serving, 503 once dead/stopped.
* ``POST /v1/cancel``    body ``{"rid": N}`` → explicit abort.

Shutdown (SIGINT/SIGTERM or ``--duration``) is a graceful drain: admission
stops, in-flight streams run to completion or deadline, and the process
exits nonzero if any engine still holds allocated pages afterwards — the
zero-leak contract, enforced at the process boundary.

Usage:
  PYTHONPATH=src python -m repro.launch.api --arch qwen2.5-7b --port 8080
  PYTHONPATH=src python -m repro.launch.api --selftest   # no fixed port
"""
from __future__ import annotations

import argparse
import asyncio
import json
import signal
import sys

from repro.cluster.gateway import AdmissionRejected, Gateway, GatewayClosed
from repro.cluster.runtime import PoolRuntime, WallClock
from repro.configs import get_config
from repro.core.request import Kind


def _response(status: str, body: bytes,
              content_type: str = "application/json") -> bytes:
    return (f"HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\n"
            f"Content-Length: {len(body)}\r\nConnection: close\r\n\r\n"
            ).encode() + body


def _json_response(status: str, obj) -> bytes:
    return _response(status, json.dumps(obj).encode() + b"\n")


async def _read_request(reader: asyncio.StreamReader):
    """Parse one HTTP request: (method, path, body) or None on junk/EOF."""
    line = await reader.readline()
    if not line:
        return None
    parts = line.decode("latin-1").split()
    if len(parts) < 2:
        return None
    method, path = parts[0].upper(), parts[1]
    length = 0
    while True:
        hdr = await reader.readline()
        if hdr in (b"\r\n", b"\n", b""):
            break
        name, _, value = hdr.decode("latin-1").partition(":")
        if name.strip().lower() == "content-length":
            try:
                length = int(value.strip())
            except ValueError:
                return None
    body = await reader.readexactly(length) if length else b""
    return method, path, body


async def _handle_generate(gateway: Gateway, body: bytes,
                           writer: asyncio.StreamWriter) -> None:
    try:
        spec = json.loads(body or b"{}")
        prompt = [int(t) for t in spec["prompt"]]
    except (json.JSONDecodeError, KeyError, TypeError, ValueError):
        writer.write(_json_response(
            "400 Bad Request", {"error": "body must be JSON with a "
                                "'prompt' list of token ids"}))
        return
    kind = Kind.OFFLINE if spec.get("kind") == "offline" else Kind.ONLINE
    try:
        stream = await gateway.submit(
            prompt, kind=kind,
            max_new_tokens=int(spec.get("max_new_tokens", 16)),
            ttft_deadline=spec.get("ttft_deadline"),
            total_deadline=spec.get("total_deadline"))
    except AdmissionRejected as exc:
        writer.write(_json_response("429 Too Many Requests",
                                    {"error": str(exc)}))
        return
    except GatewayClosed as exc:
        writer.write(_json_response("503 Service Unavailable",
                                    {"error": str(exc)}))
        return
    except ValueError as exc:
        writer.write(_json_response("400 Bad Request", {"error": str(exc)}))
        return
    writer.write(b"HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\n"
                 b"Connection: close\r\n\r\n")
    writer.write(json.dumps({"rid": stream.rid}).encode() + b"\n")
    try:
        await writer.drain()
        async for tok in stream:
            writer.write(json.dumps({"token": tok}).encode() + b"\n")
            await writer.drain()
        writer.write(json.dumps({"done": stream.outcome}).encode() + b"\n")
    except (ConnectionError, asyncio.CancelledError):
        # mid-stream disconnect: free the server-side state and re-raise
        # cancellation (the event loop owns task teardown)
        await stream.cancel()
        raise


async def _handle(gateway: Gateway, reader: asyncio.StreamReader,
                  writer: asyncio.StreamWriter) -> None:
    try:
        parsed = await _read_request(reader)
        if parsed is None:
            return
        method, path, body = parsed
        if method == "GET" and path == "/healthz":
            health = gateway.health()
            status = ("200 OK" if health["status"] in ("ok", "degraded")
                      else "503 Service Unavailable")
            writer.write(_json_response(status, health))
        elif method == "POST" and path == "/v1/generate":
            await _handle_generate(gateway, body, writer)
        elif method == "POST" and path == "/v1/cancel":
            try:
                rid = int(json.loads(body or b"{}")["rid"])
            except (json.JSONDecodeError, KeyError, TypeError, ValueError):
                writer.write(_json_response(
                    "400 Bad Request", {"error": "body must be JSON with "
                                        "an integer 'rid'"}))
            else:
                live = await gateway.cancel(rid)
                writer.write(_json_response("200 OK", {"rid": rid,
                                                       "cancelled": live}))
        else:
            writer.write(_json_response("404 Not Found",
                                        {"error": f"no route {method} {path}"}))
        await writer.drain()
    except (ConnectionError, asyncio.IncompleteReadError):
        pass
    finally:
        try:
            writer.close()
            await writer.wait_closed()
        except (ConnectionError, RuntimeError):
            pass


def build_runtime(args) -> PoolRuntime:
    cfg = get_config(args.arch).reduced()
    return PoolRuntime(
        cfg, policy=args.policy, n_strict=args.strict,
        n_relaxed=args.relaxed, clock=WallClock(),
        slo_ttft=args.slo_ttft, slo_tpot=args.slo_tpot,
        num_pages=args.num_pages, page_size=args.page_size, seed=args.seed,
        backend=args.backend, max_online_queue=args.max_online_queue,
        max_offline_backlog=args.max_offline_backlog,
        fault_plan=args.fault_plan, chaos_seed=args.chaos_seed)


async def _selftest(gateway: Gateway, host: str, port: int) -> None:
    """In-process smoke of the HTTP surface: one streamed completion, one
    mid-stream disconnect, one cancel endpoint call, one health probe."""
    async def post(path: str, obj, read_all: bool = True) -> bytes:
        reader, writer = await asyncio.open_connection(host, port)
        body = json.dumps(obj).encode()
        writer.write(f"POST {path} HTTP/1.1\r\nHost: x\r\n"
                     f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
        await writer.drain()
        data = await reader.read() if read_all else await reader.readline()
        writer.close()
        return data

    prompt = list(range(1, 9))
    full = await post("/v1/generate", {"prompt": prompt, "max_new_tokens": 4})
    assert b'"done": "finished"' in full, full

    # disconnect mid-stream: open, read the rid line, slam the connection
    reader, writer = await asyncio.open_connection(host, port)
    body = json.dumps({"prompt": prompt, "max_new_tokens": 64}).encode()
    writer.write(b"POST /v1/generate HTTP/1.1\r\nHost: x\r\n"
                 + f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
    await writer.drain()
    while b'"rid"' not in await reader.readline():
        pass
    writer.close()

    reader, writer = await asyncio.open_connection(host, port)
    writer.write(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n")
    await writer.drain()
    health = await reader.read()
    writer.close()
    assert b"200 OK" in health, health
    print("selftest: generate/disconnect/healthz OK")


async def serve(args) -> int:
    runtime = build_runtime(args)
    gateway = Gateway(runtime)
    await gateway.start()
    server = await asyncio.start_server(
        lambda r, w: _handle(gateway, r, w), args.host, args.port)
    port = server.sockets[0].getsockname()[1]
    print(f"gateway listening on {args.host}:{port} "
          f"(policy={args.policy}, strict={args.strict}, "
          f"relaxed={args.relaxed})")
    stop = asyncio.Event()
    loop = asyncio.get_running_loop()
    for sig in (signal.SIGINT, signal.SIGTERM):
        try:
            loop.add_signal_handler(sig, stop.set)
        except (NotImplementedError, RuntimeError):
            pass
    if args.selftest:
        await _selftest(gateway, "127.0.0.1", port)
        stop.set()
    elif args.duration is not None:
        loop.call_later(args.duration, stop.set)
    await stop.wait()
    server.close()
    await server.wait_closed()
    report = await gateway.drain(timeout=args.drain_timeout)
    leaks = {k: v for k, v in report["leaked_pages"].items() if v}
    print(json.dumps({"drained": report["drained"],
                      "leaked_pages": report["leaked_pages"]}, indent=2))
    if leaks:
        print(f"LEAK: pages still allocated after drain: {leaks}",
              file=sys.stderr)
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-7b")
    ap.add_argument("--policy", default="ooco")
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "pallas", "interpret", "ref"])
    ap.add_argument("--strict", type=int, default=1)
    ap.add_argument("--relaxed", type=int, default=1)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080,
                    help="0 picks an ephemeral port")
    ap.add_argument("--slo-ttft", type=float, default=2.0)
    ap.add_argument("--slo-tpot", type=float, default=0.05)
    ap.add_argument("--num-pages", type=int, default=1024)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-online-queue", type=int, default=64,
                    help="bounded online admission queue: overflow answers "
                         "429 instead of growing host state (None-like 0 "
                         "disables the bound)")
    ap.add_argument("--max-offline-backlog", type=int, default=None,
                    help="bounded offline backlog: overflow is shed through "
                         "admission_decision (surfaced, never silent)")
    ap.add_argument("--duration", type=float, default=None,
                    help="serve for N seconds then drain (default: until "
                         "SIGINT/SIGTERM)")
    ap.add_argument("--drain-timeout", type=float, default=60.0)
    ap.add_argument("--fault-plan", default=None,
                    help="deterministic chaos, same spec as repro.launch.serve")
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--selftest", action="store_true",
                    help="bind an ephemeral port, run an in-process HTTP "
                         "smoke (stream, disconnect, healthz), drain, exit")
    args = ap.parse_args(argv)
    if args.selftest:
        args.port = 0
    if args.max_online_queue is not None and args.max_online_queue <= 0:
        args.max_online_queue = None
    return asyncio.run(serve(args))


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
