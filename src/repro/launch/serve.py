"""Serving launcher: real OOCO co-located serving on this host (CPU-scale).

Composes one latency-relaxed + one latency-strict ServingEngine (the paper's
1+1 evaluation topology), drives them with a trace, and applies the OOCO
scheduling points with *measured* step latencies feeding the SLO decisions.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-7b --policy ooco \
      --duration 30 --online-qps 0.5 --offline-qps 1.0
"""
from __future__ import annotations

import argparse
import random
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import scheduling as sch
from repro.core.hardware import cpu_measured
from repro.core.perf_model import PerfModel
from repro.core.request import Kind, Phase, Request
from repro.data import traces as tr
from repro.engine.engine import ServingEngine
from repro.models.model import build_model


class CoLocatedServer:
    """1 relaxed + 1 strict engine + the OOCO scheduling points (§3.4)."""

    def __init__(self, cfg, *, policy: str = "ooco", slo_tpot: float = 1.0,
                 num_pages: int = 1024, page_size: int = 16, seed: int = 0,
                 backend: str = "auto"):
        self.cfg = cfg
        self.policy = policy
        self.slo_tpot = slo_tpot
        self.backend = backend
        self.clock = time.perf_counter  # drivers override with trace-relative time
        # §3.4.1: the layer-level preemption predicate polls this between
        # transformer layers. Drivers wire it to their live arrival feed
        # (a real deployment polls the RPC queue); default checks only the
        # already-submitted queue.
        self.incoming_online = lambda: False
        model = build_model(cfg, remat=False)
        params = model.init(jax.random.PRNGKey(seed))
        # one decode bucket bounds jit-compilation variants on cold start
        self.relaxed = ServingEngine(model, params, num_pages=num_pages,
                                     page_size=page_size, decode_buckets=(8,),
                                     backend=backend)
        self.strict = ServingEngine(model, params, num_pages=num_pages,
                                    page_size=page_size, decode_buckets=(8,),
                                    backend=backend)
        self.pm = PerfModel(cfg, cpu_measured())
        self.rng = random.Random(seed)
        self.online_queue: list[tuple[Request, list[int]]] = []
        self.offline_queue: list[tuple[Request, list[int]]] = []
        self.strict_online: list[Request] = []
        self.strict_offline: list[Request] = []
        self.relaxed_offline: list[Request] = []
        self.finished: list[Request] = []
        self.measured_tpot: float = slo_tpot / 4  # running estimate

    def submit(self, req: Request, tokens: list[int]) -> None:
        q = self.online_queue if req.kind == Kind.ONLINE else self.offline_queue
        q.append((req, tokens))

    # ------------------------------------------------------------------
    def _prefill_one(self) -> bool:
        """One prefill action on the relaxed engine; returns True if it did work."""
        if self.online_queue:
            req, toks = self.online_queue.pop(0)
            self.relaxed.add_request(req, toks)
            self.relaxed.prefill(req.rid)
            req.first_token_time = self.clock()
            self._migrate_to_strict(req)
            return True
        if self.offline_queue:
            req, toks = self.offline_queue.pop(0)
            # §3.4.1: interrupt offline prefill the moment online work arrives
            preempt = (lambda: bool(self.online_queue) or self.incoming_online()) \
                if self.policy == "ooco" else None
            self.relaxed.add_request(req, toks)
            status = self.relaxed.prefill(req.rid, should_preempt=preempt)
            if status == "preempted":
                req.phase = Phase.QUEUED
                self.offline_queue.insert(0, (req, toks))
                return True
            req.first_token_time = req.first_token_time or self.clock()
            if self.policy == "ooco":
                self.relaxed_offline.append(req)   # decode on relaxed until pulled
            else:
                self._migrate_to_strict(req)
            return True
        return False

    def _migrate_to_strict(self, req: Request) -> None:
        k, v, n = self.relaxed.migrate_out(req.rid)
        self.strict.migrate_in(req.rid, req, self.relaxed.token_buf[req.rid],
                               k, v, n,
                               sampling=self.relaxed.req_sampling.pop(req.rid, None))
        (self.strict_online if req.kind == Kind.ONLINE
         else self.strict_offline).append(req)

    def _strict_step(self) -> None:
        self.strict_online = [r for r in self.strict_online if not r.done]
        self.strict_offline = [r for r in self.strict_offline if not r.done]
        online, offline = self.strict_online, self.strict_offline
        if not online and not offline:
            return
        if self.policy == "base_pd":
            batch = online + offline
        elif self.policy == "online_priority":
            batch = online + offline[: max(0, 4 - len(online))]
        else:
            # measured-latency calibrated mix decoding: scale the perf-model
            # SLO bound by the observed/predicted latency ratio
            pred = self.pm.decode_estimate(
                [r.context_len for r in online + offline[:1]]).latency or 1e-6
            scale = self.measured_tpot / pred
            batch = sch.mix_decoding_selection(
                online, offline, self.slo_tpot / max(scale, 1e-6), self.pm,
                rng=self.rng)
        t0 = time.perf_counter()
        self.strict.decode_step([r.rid for r in batch])
        dt = time.perf_counter() - t0
        self.measured_tpot = 0.8 * self.measured_tpot + 0.2 * dt
        for r in batch:
            if r.done:
                self.finished.append(r)

    def _relaxed_decode_step(self) -> None:
        self.relaxed_offline = [r for r in self.relaxed_offline if not r.done]
        if not self.relaxed_offline:
            return
        batch = self.relaxed_offline[:16]
        self.relaxed.decode_step([r.rid for r in batch])
        # §3.4.3 pull: strict node absorbs offline decodes when it has headroom
        if self.measured_tpot < 0.5 * self.slo_tpot and self.strict_online:
            pref = sch.select_for_migration(
                batch, sch.LengthPreference(batch[0].context_len, "shortest", 1))
            for r in pref:
                if r.done:
                    continue
                self.relaxed_offline.remove(r)
                self._migrate_to_strict(r)
        for r in batch:
            if r.done:
                self.finished.append(r)

    def step(self) -> None:
        """One co-located scheduling round (prefill + both decode pools)."""
        self._prefill_one()
        self._strict_step()
        if self.policy == "ooco":
            self._relaxed_decode_step()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-7b")
    ap.add_argument("--policy", default="ooco",
                    choices=["base_pd", "online_priority", "ooco"])
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "pallas", "interpret", "ref"],
                    help="attention backend: auto = Pallas kernels on TPU, "
                         "XLA/jnp reference on CPU")
    ap.add_argument("--duration", type=float, default=20.0)
    ap.add_argument("--online-qps", type=float, default=0.5)
    ap.add_argument("--offline-qps", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    server = CoLocatedServer(cfg, policy=args.policy, backend=args.backend)
    rng = np.random.default_rng(args.seed)
    online = tr.online_trace("ooc", duration=args.duration,
                             mean_qps=args.online_qps, seed=args.seed)
    n_off = int(args.offline_qps * args.duration)
    offline = tr.with_uniform_qps(tr.offline_requests(n_off), args.offline_qps)

    def toks(n):
        return list(rng.integers(0, cfg.vocab_size, max(min(n, 64), 4)))

    pending = sorted(
        [(t.arrival, Kind.ONLINE, t) for t in online]
        + [(t.arrival, Kind.OFFLINE, t) for t in offline])
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < args.duration or pending:
        now = time.perf_counter() - t0
        while pending and pending[0][0] <= now:
            _, kind, t = pending.pop(0)
            p = toks(t.prompt_len)
            req = Request(kind, now, len(p), min(t.output_len, 32))
            server.submit(req, p)
        server.step()
        if now > args.duration:
            break
    on = [r for r in server.finished if r.kind == Kind.ONLINE]
    off = [r for r in server.finished if r.kind == Kind.OFFLINE]
    off_tokens = sum(r.generated for r in off)
    print(f"policy={args.policy} finished online={len(on)} offline={len(off)} "
          f"offline_tokens={off_tokens} "
          f"offline_tok/s={off_tokens / args.duration:.1f} "
          f"preemptions={server.relaxed.stats.preemptions}")


if __name__ == "__main__":
    main()
