"""Serving launcher: real OOCO co-located serving on this host (CPU-scale).

Drives the pool-based runtime (``repro.cluster.runtime.PoolRuntime``):
N latency-strict + M latency-relaxed ServingEngines, the OOCO scheduling
points (§3.4) routed through the roofline perf model, and a pluggable clock
— wall-clock for live serving, virtual clock for deterministic trace replay
(same seed → bit-identical token streams and metrics JSON).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-7b --policy ooco \
      --strict 1 --relaxed 2 --virtual-clock --duration 20
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

from repro.cluster.runtime import (POLICIES, PoolRuntime, VirtualClock,
                                   WallClock, replay_hw)
from repro.configs import get_config
from repro.data import traces as tr


def write_json_atomic(path: str, blob: str) -> None:
    """Write via a same-directory temp file + ``os.replace`` so a crash
    mid-write can never leave a truncated/corrupt metrics file: readers see
    either the previous complete file or the new complete file."""
    d = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(dir=d, prefix=os.path.basename(path) + ".")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(blob)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


class CoLocatedServer(PoolRuntime):
    """PR-1 compatibility wrapper: the fixed 1-relaxed + 1-strict topology
    as a special case of the pool runtime (same ``submit``/``step`` API).
    Keeps the legacy whole-prompt prefill with layer-level interruption
    (``chunk_tokens=0``) — the PR-1 semantics its tests pin down."""

    def __init__(self, cfg, *, policy: str = "ooco", slo_tpot: float = 1.0,
                 num_pages: int = 1024, page_size: int = 16, seed: int = 0,
                 backend: str = "auto"):
        super().__init__(cfg, policy=policy, n_strict=1, n_relaxed=1,
                         clock=WallClock(), slo_tpot=slo_tpot,
                         num_pages=num_pages, page_size=page_size, seed=seed,
                         backend=backend, decode_buckets=(8,),
                         chunk_tokens=0, decode_horizon=1)

    @property
    def relaxed(self):
        return self.relaxed_pool[0].engine

    @property
    def strict(self):
        return self.strict_pool[0].engine


def build_traces(args, cfg):
    online = tr.online_trace("ooc", duration=args.duration,
                             mean_qps=args.online_qps, seed=args.seed)
    n_off = max(int(args.offline_qps * args.duration), 1)
    if args.trace == "shared-prefix":
        # P system prompts x Q few-shot variants x R queries with explicit
        # token content — the cross-request KV-reuse workload; sized to
        # the same offline request count as the ooc trace
        reqs = tr.shared_prefix_requests(
            num_prefixes=max(n_off // 8, 1), variants=2, queries=4,
            prefix_tokens=args.max_prompt // 2,
            variant_tokens=args.max_prompt // 8,
            query_tokens=args.max_prompt // 8,
            vocab=cfg.vocab_size, seed=args.seed + 1)[:n_off]
        offline = tr.with_uniform_qps(reqs, args.offline_qps)
    else:
        offline = tr.with_uniform_qps(
            tr.offline_requests(n_off, seed=args.seed + 1), args.offline_qps)
    return online, offline


def _auto_or_nonneg_int(knob):
    """argparse type: 'auto' or an int >= 0 (0 disables the feature).
    Raises ``ArgumentTypeError`` so junk exits with a one-line usage error
    instead of a deep ValueError traceback from the runtime."""
    def parse(s):
        if s == "auto":
            return s
        try:
            n = int(s)
        except ValueError:
            raise argparse.ArgumentTypeError(
                f"{knob} must be 'auto' or an integer >= 0 (got {s!r})")
        if n < 0:
            raise argparse.ArgumentTypeError(
                f"{knob} must be >= 0 (got {n}; 0 disables the feature)")
        return n
    return parse


def _positive_int(knob):
    """argparse type: an int >= 1."""
    def parse(s):
        try:
            n = int(s)
        except ValueError:
            raise argparse.ArgumentTypeError(
                f"{knob} must be an integer >= 1 (got {s!r})")
        if n < 1:
            raise argparse.ArgumentTypeError(
                f"{knob} must be >= 1 (got {n}; omit it for unbounded)")
        return n
    return parse


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-7b")
    ap.add_argument("--policy", default="ooco", choices=list(POLICIES))
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "pallas", "interpret", "ref"],
                    help="attention backend: auto = Pallas kernels on TPU, "
                         "XLA/jnp reference on CPU")
    ap.add_argument("--strict", type=int, default=1,
                    help="latency-strict pool size (decode under TPOT SLO)")
    ap.add_argument("--relaxed", type=int, default=1,
                    help="latency-relaxed pool size (prefill + offline decode)")
    ap.add_argument("--virtual-clock", action="store_true",
                    help="deterministic trace replay: time advances by the "
                         "perf model instead of the wall clock")
    ap.add_argument("--chunk-tokens", default="auto",
                    type=_auto_or_nonneg_int("--chunk-tokens"),
                    help="chunked-prefill token budget per fused mixed "
                         "step: 'auto' picks it from the roofline ridge "
                         "(PerfModel.suggest_chunk_tokens), N fixes it, "
                         "0 disables chunking (legacy whole-prompt prefill "
                         "with layer-level interruption)")
    ap.add_argument("--decode-horizon", default="auto",
                    type=_auto_or_nonneg_int("--decode-horizon"),
                    help="multi-step decode horizon on latency-relaxed "
                         "rounds: 'auto' picks K from the decode roofline "
                         "(PerfModel.suggest_decode_horizon, amortizing the "
                         "per-dispatch overhead under the §3.4.1 preemption "
                         "bound), N fixes it, 1 disables fusion (one host "
                         "sync per token — today's behavior)")
    ap.add_argument("--trace", default="ooc",
                    choices=["ooc", "shared-prefix"],
                    help="offline workload: 'ooc' draws lengths from the "
                         "paper's Table-5 statistics; 'shared-prefix' "
                         "generates P system prompts x Q few-shot variants "
                         "x R queries with explicit token content (the "
                         "cross-request KV-reuse workload)")
    ap.add_argument("--prefix-cache", default="on", choices=["on", "off"],
                    help="cross-request KV reuse: radix prefix cache over "
                         "resident pages with refcounted copy-on-write "
                         "sharing (chunked-prefill path only; greedy token "
                         "streams are bit-identical either way)")
    ap.add_argument("--duration", type=float, default=20.0)
    ap.add_argument("--online-qps", type=float, default=0.5)
    ap.add_argument("--offline-qps", type=float, default=1.0)
    ap.add_argument("--slo-ttft", type=float, default=2.0)
    ap.add_argument("--slo-tpot", type=float, default=0.05)
    ap.add_argument("--num-pages", type=int, default=1024)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-prompt", type=int, default=64)
    ap.add_argument("--max-output", type=int, default=32)
    ap.add_argument("--metrics-json", default=None,
                    help="write the metrics summary to this path "
                         "(atomically: temp file + os.replace)")
    ap.add_argument("--tokens-json", default=None,
                    help="write the finished-request signature (per-request "
                         "identity + full token stream) to this path — the "
                         "chaos-replay CI job byte-diffs it across runs")
    ap.add_argument("--fault-plan", default=None,
                    help="deterministic fault injection for chaos replay: "
                         "a JSON file/list of events or the compact spec "
                         "'kind[:engine][@t][:k=v...]', comma-separated. "
                         "Kinds: crash, stuck, page_leak, migration_fail, "
                         "migration_corrupt, migration_flaky. Example: "
                         "'crash:relaxed1@3.0,migration_flaky:p=0.25'. "
                         "Same plan + --chaos-seed => bit-identical metrics "
                         "and token streams under --virtual-clock")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="seed for the fault injector's RNG (flaky-transfer "
                         "coin flips, retry-backoff jitter); replays with "
                         "the same seed are bit-reproducible")
    ap.add_argument("--max-online-queue",
                    type=_positive_int("--max-online-queue"), default=None,
                    help="bounded online admission queue: overflowing "
                         "submits raise AdmissionRejected (backpressure) "
                         "instead of growing host state without bound")
    ap.add_argument("--replay-hw", default="cpu", choices=["cpu", "v5e"],
                    help="virtual-clock hardware calibration preset: 'cpu' "
                         "scales rates to CPU-smoke-test sizes; 'v5e' keeps "
                         "the real TPU v5e dispatch overheads against "
                         "uniformly scaled rates — the datacenter "
                         "overhead:work ratio where horizons pay "
                         "(ignored without --virtual-clock)")
    return ap


def main(argv=None):
    args = build_parser().parse_args(argv)

    cfg = get_config(args.arch).reduced()
    clock = VirtualClock() if args.virtual_clock else WallClock()
    hw = replay_hw(args.replay_hw) if args.virtual_clock else None
    chunk = args.chunk_tokens
    horizon = args.decode_horizon
    runtime = PoolRuntime(cfg, policy=args.policy, n_strict=args.strict,
                          n_relaxed=args.relaxed, clock=clock,
                          slo_ttft=args.slo_ttft, slo_tpot=args.slo_tpot,
                          num_pages=args.num_pages, seed=args.seed,
                          backend=args.backend, hw=hw, chunk_tokens=chunk,
                          decode_horizon=horizon,
                          prefix_cache=args.prefix_cache == "on",
                          fault_plan=args.fault_plan,
                          chaos_seed=args.chaos_seed,
                          max_online_queue=args.max_online_queue)
    online, offline = build_traces(args, cfg)
    summary = runtime.run(online, offline, duration=args.duration,
                          max_prompt=args.max_prompt,
                          max_output=args.max_output)
    blob = json.dumps(summary, sort_keys=True, indent=2)
    print(blob)
    if args.metrics_json:
        write_json_atomic(args.metrics_json, blob + "\n")
    if args.tokens_json:
        write_json_atomic(
            args.tokens_json,
            json.dumps(runtime.finished_signature()) + "\n")
    return summary


if __name__ == "__main__":
    main(sys.argv[1:])
