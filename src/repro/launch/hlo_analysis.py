"""Post-compile HLO analysis for the roofline report.

XLA's ``cost_analysis()`` counts while-loop (lax.scan) bodies ONCE, so a
scan-over-layers model under-reports FLOPs/bytes by ~num_layers x. This
module walks the *partitioned* HLO text (per-device shapes), multiplies
while bodies by their parsed trip counts, and extracts:

  * dot FLOPs (2 * prod(result) * prod(contracting dims)) — the MXU work;
  * collective bytes by op kind (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute), summed over result sizes.

Trip counts are read from the while condition's compare-to-constant pattern
(the form lax.scan emits); unparseable conditions fall back to 1 and are
reported so the analytic cross-check (perf model) can flag the gap.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+) (?:\([^)]*\) -> .*?)?\{?\s*$")
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """bytes of 'bf16[8,128,256]' (tuples handled by caller)."""
    m = _SHAPE_RE.match(type_str)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def _all_shapes_bytes(segment: str) -> int:
    return sum(_shape_bytes(m.group(0)) for m in _SHAPE_RE.finditer(segment))


@dataclass
class Computation:
    name: str
    lines: list[str] = field(default_factory=list)


def _split_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if not line.startswith(" ") and ("{" in line) and ("->" in line or line.startswith(("ENTRY", "%"))):
            name = line.split()[0].lstrip("%")
            if name == "ENTRY":
                name = line.split()[1].lstrip("%")
            cur = Computation(name)
            comps[cur.name] = cur
        elif cur is not None:
            if stripped == "}":
                cur = None
            else:
                cur.lines.append(stripped)
    return comps


_CALLEE_RE = re.compile(
    r"(?:body|condition|to_apply|called_computations|calls)=\{?%?([\w\.\-]+)")
_CONST_RE = re.compile(r"= s32\[\] constant\((\d+)\)")


def _trip_count(cond: Computation) -> int:
    """Largest s32 constant in the condition ≈ trip count (lax.scan form)."""
    best = 1
    for line in cond.lines:
        for m in _CONST_RE.finditer(line):
            best = max(best, int(m.group(1)))
    return best


_DOT_RE = re.compile(
    r"= (\w+\[[\d,]*\])\S* dot\(.*?lhs_contracting_dims=\{([\d,]*)\}")
_DOT_OPERAND_RE = re.compile(r"dot\(\s*%?([\w\.\-]+)\s*[,)]")
# older XLA prints operand types inline: dot(f32[64,128]{1,0} %convert.15, ...)
_DOT_LHS_INLINE_RE = re.compile(r"dot\(\s*\w+\[([\d,]*)\]")


def analyze(hlo: str) -> dict:
    comps = _split_computations(hlo)
    # entry: prefer the "main*" computation, else the one never called
    entry = next((n for n in comps if n.startswith("main")), None)
    if entry is None:
        referenced = set()
        for c in comps.values():
            for line in c.lines:
                for m in _CALLEE_RE.finditer(line):
                    referenced.add(m.group(1))
        entries = [n for n in comps if n not in referenced]
        entry = entries[-1] if entries else next(iter(comps), None)

    def comp_cost(name: str, memo: dict) -> dict:
        if name in memo:
            return memo[name]
        c = comps.get(name)
        out = {"dot_flops": 0.0, "collectives": defaultdict(float), "unparsed_while": 0}
        if c is None:
            memo[name] = out
            return out
        # instruction result types for operand-shape lookup
        result_types: dict[str, str] = {}
        for line in c.lines:
            mm = re.match(r"%?([\w\.\-]+) = (\([^)]*\)|\w+\[[\d,]*\]\S*)", line)
            if mm:
                result_types[mm.group(1)] = mm.group(2)
        for line in c.lines:
            # dots
            md = _DOT_RE.search(line)
            if md and " dot(" in line:
                res_bytes_shape = md.group(1)
                m_res = _SHAPE_RE.match(res_bytes_shape)
                prod_res = 1
                for d in m_res.group(2).split(","):
                    if d:
                        prod_res *= int(d)
                # contracting dim sizes from the lhs operand's type — either
                # printed inline (older XLA) or looked up by operand name
                k = 1
                dims: list[int] = []
                mi = _DOT_LHS_INLINE_RE.search(line)
                if mi:
                    dims = [int(x) for x in mi.group(1).split(",") if x]
                else:
                    mo = _DOT_OPERAND_RE.search(line)
                    if mo:
                        t = result_types.get(mo.group(1).lstrip("%"))
                        ms = _SHAPE_RE.match(t) if t else None
                        if ms:
                            dims = [int(x) for x in ms.group(2).split(",") if x]
                if dims:
                    for ci in md.group(2).split(","):
                        if ci and int(ci) < len(dims):
                            k *= dims[int(ci)]
                out["dot_flops"] += 2.0 * prod_res * k
                continue
            # collectives
            for kind in COLLECTIVES:
                if f" {kind}(" in line or f" {kind}-start(" in line:
                    head = line.split("=", 1)[0] + "= " + line.split("=", 1)[1]
                    res_t = line.split("=", 1)[1].strip().split(" ")[0]
                    out["collectives"][kind] += _all_shapes_bytes(res_t)
                    break
            # nested calls
            if " while(" in line:
                mb = re.search(r"body=\{?%?([\w\.\-]+)", line)
                mc = re.search(r"condition=\{?%?([\w\.\-]+)", line)
                body = mb.group(1) if mb else None
                cond = mc.group(1) if mc else None
                trips = _trip_count(comps[cond]) if cond in comps else 1
                if trips <= 1:
                    out["unparsed_while"] += 1
                sub = comp_cost(body, memo) if body else {"dot_flops": 0,
                                                          "collectives": {}}
                out["dot_flops"] += trips * sub["dot_flops"]
                for k2, v in sub["collectives"].items():
                    out["collectives"][k2] += trips * v
                out["unparsed_while"] += trips * sub.get("unparsed_while", 0)
            else:
                for m in re.finditer(
                        r"(?:to_apply|called_computations|calls)=\{?%?([\w\.\-]+)",
                        line):
                    callee = m.group(1)
                    if callee in comps:
                        sub = comp_cost(callee, memo)
                        out["dot_flops"] += sub["dot_flops"]
                        for k2, v in sub["collectives"].items():
                            out["collectives"][k2] += v
                        out["unparsed_while"] += sub.get("unparsed_while", 0)
        memo[name] = out
        return out

    memo: dict = {}
    res = comp_cost(entry, memo)
    total_coll = sum(res["collectives"].values())
    return {
        "entry": entry,
        "dot_flops_per_device": res["dot_flops"],
        "collective_bytes_per_device": total_coll,
        "collective_breakdown": dict(res["collectives"]),
        "unparsed_whiles": res["unparsed_while"],
    }
