"""Production mesh construction (assignment: 16x16 per pod, 2 pods)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    auto = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=auto)


def mesh_shape_dict(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
