"""Production mesh construction (assignment: 16x16 per pod, 2 pods)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    # jax.sharding.AxisType (explicit-sharding API) only exists on newer jax;
    # older versions treat every axis as Auto already.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def mesh_shape_dict(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
