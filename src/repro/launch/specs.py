"""ShapeDtypeStruct stand-ins for every workload input (no allocation).

``input_specs(cfg, shape)`` returns the abstract batch for a workload shape;
``abstract_state(...)`` builds abstract params / optimizer / cache pytrees.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import AUDIO, VLM, InputShape, ModelConfig
from repro.training.optimizer import init_opt_state

TOKENS = jnp.int32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """Abstract input batch for (arch, workload shape).

    VLM: image patch tokens are part of the sequence budget, so text tokens
    = seq_len - num_frontend_tokens. Audio: seq_len maps to encoder frames
    (the stubbed conv frontend's output), decoder prompt is small.
    """
    B, S = shape.global_batch, shape.seq_len
    d = cfg.jnp_dtype
    if shape.kind == "decode":
        return {"tokens": _sds((B,), TOKENS)}
    if cfg.family == VLM:
        T = cfg.num_frontend_tokens
        batch = {"tokens": _sds((B, S - T), TOKENS),
                 "frontend_embeds": _sds((B, T, cfg.d_model), d)}
    elif cfg.family == AUDIO:
        dec = 64 if shape.kind == "train" else 8
        batch = {"tokens": _sds((B, dec), TOKENS),
                 "frontend_embeds": _sds((B, S, cfg.d_model), d)}
    else:
        batch = {"tokens": _sds((B, S), TOKENS)}
    if shape.kind == "train":
        batch["labels"] = _sds(batch["tokens"].shape, TOKENS)
    return batch


def abstract_params(model):
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def abstract_opt_state(params_abs):
    return jax.eval_shape(init_opt_state, params_abs)


def abstract_cache(model, shape: InputShape):
    cfg = model.cfg
    kw = {}
    if cfg.family == AUDIO:
        kw["enc_len"] = 1500
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                 prefilled_len=shape.seq_len - 1, **kw))
