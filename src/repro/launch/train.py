"""Training launcher: run a reduced-config model for N steps on this host,
or lower the full train_4k shape via the dry-run path.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --steps 200 --seq 128 --batch 8
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.models.model import build_model
from repro.training.data_pipeline import DataConfig, packed_batches
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import train
from repro.training import checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--full-size", action="store_true",
                    help="use the full assigned config (dry-run scale!)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = cfg.reduced(layers=args.layers, d_model=args.d_model, vocab=2048)
    print(f"training {cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab_size} (~{cfg.num_params()/1e6:.1f}M params)")
    model = build_model(cfg, remat=True)
    params = model.init(jax.random.PRNGKey(args.seed))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    batch_size=args.batch, seed=args.seed)
    opt = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                      total_steps=args.steps)
    ckpt_fn = None
    if args.ckpt:
        ckpt_fn = lambda p, o, s: checkpoint.save(args.ckpt, p, o, s)
    params, opt_state, hist = train(model, params, packed_batches(dc, args.steps),
                                    opt, checkpoint_fn=ckpt_fn,
                                    checkpoint_every=max(args.steps // 2, 1))
    if args.ckpt:
        checkpoint.save(args.ckpt, params, opt_state, args.steps)
        print(f"saved {args.ckpt}")
    first, last = hist[0][1], hist[-1][1]
    print(f"loss {first:.3f} -> {last:.3f} ({(first-last)/first:.0%} reduction)")


if __name__ == "__main__":
    main()
