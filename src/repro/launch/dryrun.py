"""Multi-pod dry run: lower + compile every (architecture x input shape) on
the production meshes, extract memory / cost / collective analyses, and emit
the roofline terms (assignment: MULTI-POD DRY-RUN + ROOFLINE ANALYSIS).

The device-count XLA flag below MUST precede every other import that could
initialize jax — including `from repro...` — since jax locks the device count
on first backend init.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out f.json]
"""
from __future__ import annotations

import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

import argparse
import json
import time
import traceback

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED, get_config
from repro.core.hardware import TPU_V5E
from repro.core.perf_model import PerfModel
from repro.launch import hlo_analysis, specs as sp
from repro.launch.mesh import make_production_mesh, mesh_shape_dict
from repro.models.config import INPUT_SHAPES, AUDIO, ModelConfig
from repro.models.model import build_model
from repro.sharding import rules
from repro.sharding.ctx import activate, standard_mapping
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import make_train_step

# long_500k skip set (DESIGN.md §4: pure full-attention archs + enc-dec)
LONG_SKIP = {
    "phi-3-vision-4.2b": "pure full attention (no sub-quadratic variant)",
    "tinyllama-1.1b": "pure full attention",
    "granite-moe-3b-a800m": "pure full attention",
    "qwen3-8b": "pure full attention",
    "qwen2.5-32b": "pure full attention",
    "whisper-tiny": "enc-dec with 448-position decoder; no long decode",
}


def skip_reason(arch: str, shape_name: str) -> str | None:
    if shape_name == "long_500k":
        return LONG_SKIP.get(arch)
    return None


def _named(mesh, tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s, tree,
        is_leaf=lambda x: isinstance(x, P))


def lower_case(arch: str, shape_name: str, *, multi_pod: bool = False,
               compile_: bool = True, seq_shard: str | None = "model",
               weight_mode: str | None = None, seq_attn: bool | None = None):
    """Lower + compile one (arch, shape, mesh) case; returns a result dict.

    weight_mode overrides the sharding baseline (fsdp_tp); serving shapes
    accept "tp_only"/"replicated". seq_attn forces/disables sequence-sharded
    attention (default: auto for head counts not dividing the model axis).
    (Perf iterations, EXPERIMENTS.md §Perf.)
    """
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    msd = mesh_shape_dict(mesh)
    n_chips = int(np.prod(list(msd.values())))
    dp = int(np.prod([msd[a] for a in rules.dp_axes(multi_pod)]))
    long_ctx = shape_name == "long_500k"
    if shape.kind == "train" and cfg.is_moe:
        # train with the classic 1.25 capacity factor (serving keeps 2.0 for
        # fewer drops); the capacity buffers are the MoE activation peak
        import dataclasses
        cfg = dataclasses.replace(cfg, moe_capacity_factor=1.25)
    model = build_model(cfg, long_context=long_ctx, moe_groups=dp, remat=True)

    params_abs = sp.abstract_params(model)
    wm = weight_mode or "fsdp_tp"
    pspecs = rules.param_specs(params_abs, msd, weight_mode=wm)
    batch_abs = sp.input_specs(cfg, shape)
    bspecs = rules.batch_spec(cfg, shape.kind, shape.global_batch, multi_pod, msd)
    bspecs = {k: bspecs.get(k, P(*([None] * len(v.shape))))
              for k, v in batch_abs.items()}

    t0 = time.perf_counter()
    if shape.kind == "train":
        opt_abs = sp.abstract_opt_state(params_abs)
        ospecs = type(opt_abs)(step=P(), mu=pspecs, nu=jax.tree.map(lambda s: s, pspecs))
        # grad accumulation: scan-saved layer carries scale with
        # depth x per-device microbatch, so deeper stacks get more splits
        micro = 16 if cfg.num_layers >= 56 else 8 if cfg.num_layers >= 32 else 4
        while micro > 1 and shape.global_batch % (micro * dp):
            micro //= 2
        step = make_train_step(model, AdamWConfig(), microbatches=micro)
        jitted = jax.jit(step,
                         in_shardings=(_named(mesh, pspecs), _named(mesh, ospecs),
                                       _named(mesh, bspecs)),
                         out_shardings=(_named(mesh, pspecs), _named(mesh, ospecs),
                                        None),
                         donate_argnums=(0, 1))  # update in place
        args = (params_abs, opt_abs, batch_abs)
    elif shape.kind == "prefill":
        def prefill_step(params, batch):
            return model.prefill(params, batch, cache_len=shape.seq_len)
        jitted = jax.jit(prefill_step,
                         in_shardings=(_named(mesh, pspecs), _named(mesh, bspecs)))
        args = (params_abs, batch_abs)
    else:  # decode
        cache_abs = sp.abstract_cache(model, shape)
        cspecs = rules.cache_specs(cfg, cache_abs, shape.global_batch,
                                   multi_pod, msd, seq_shard=seq_shard)
        b = rules.batch_axis(shape.global_batch, multi_pod, msd)
        tok_spec = P(b)

        def decode_step(params, tokens, cache):
            return model.decode_step(params, tokens, cache)
        jitted = jax.jit(decode_step,
                         in_shardings=(_named(mesh, pspecs),
                                       NamedSharding(mesh, tok_spec),
                                       _named(mesh, cspecs)),
                         out_shardings=(None, _named(mesh, cspecs)),
                         donate_argnums=(2,))  # KV cache updates in place
        args = (params_abs, batch_abs["tokens"], cache_abs)

    b_axes = rules.batch_axis(shape.global_batch, multi_pod, msd)
    mapping = standard_mapping(b_axes)
    if seq_attn is None:
        # auto: serving shapes with head counts not dividing the TP axis.
        # (train keeps baseline sharding: the granite train case trips an
        # XLA SPMD partitioner verifier bug when the seq-attn constraints
        # meet the autodiff gather — see EXPERIMENTS.md §Perf backlog)
        # MoE excluded: seq-sharded activations entering the group-local
        # dispatch force mass resharding (granite prefill regressed 5x).
        # Audio excluded: tiny model, no win (measured 0.9x).
        seq_attn = (shape.kind != "train"
                    and cfg.num_heads % msd["model"] != 0
                    and not cfg.is_moe
                    and cfg.family not in ("ssm", "audio"))
    if seq_attn:
        mapping["attn_q_seq"] = P(b_axes, "model", None, None)
        mapping["attn_kv_rep"] = P(b_axes, None, None, None)
        mapping["attn_q_dec"] = P(b_axes, None, None)
    with mesh, activate(mapping):
        lowered = jitted.lower(*args)
        result = {
            "arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "chips": n_chips, "lower_s": round(time.perf_counter() - t0, 1),
        }
        if not compile_:
            return result, lowered, None
        t1 = time.perf_counter()
        compiled = lowered.compile()
        result["compile_s"] = round(time.perf_counter() - t1, 1)

    mem = compiled.memory_analysis()
    result["memory"] = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
    }
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jax returns [dict]
        ca = ca[0] if ca else {}
    result["cost_analysis"] = {"flops": ca.get("flops"),
                               "bytes_accessed": ca.get("bytes accessed")}
    hlo = hlo_analysis.analyze(compiled.as_text())
    result["hlo"] = hlo
    result["roofline"] = roofline_terms(cfg, shape, hlo, n_chips, multi_pod)
    return result, lowered, compiled


def roofline_terms(cfg: ModelConfig, shape, hlo: dict, n_chips: int,
                   multi_pod: bool) -> dict:
    """Three roofline terms (seconds) from the compiled artifact + the
    analytic perf-model cross-check (EXPERIMENTS.md §Roofline)."""
    hw = TPU_V5E
    pm = PerfModel(cfg, hw, tp=1)
    # analytic per-cluster totals from the paper's own operator model
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        est = pm.prefill_estimate([shape.seq_len] * shape.global_batch)
        analytic_flops = 3.0 * est.flops        # fwd + bwd (2x fwd)
        analytic_bytes = 3.0 * est.bytes
        model_flops = 6.0 * cfg.num_active_params() * tokens
    elif shape.kind == "prefill":
        est = pm.prefill_estimate([shape.seq_len] * shape.global_batch)
        analytic_flops, analytic_bytes = est.flops, est.bytes
        model_flops = 2.0 * cfg.num_active_params() * shape.global_batch * shape.seq_len
    else:
        est = pm.decode_estimate([shape.seq_len] * shape.global_batch)
        analytic_flops, analytic_bytes = est.flops, est.bytes
        model_flops = 2.0 * cfg.num_active_params() * shape.global_batch
    dot_flops_dev = hlo["dot_flops_per_device"]
    coll_bytes_dev = hlo["collective_bytes_per_device"]
    compute_t = dot_flops_dev / hw.peak_flops
    memory_t = (analytic_bytes / n_chips) / hw.peak_hbm_bw
    collective_t = coll_bytes_dev / (50e9)
    terms = {"compute_s": compute_t, "memory_s": memory_t,
             "collective_s": collective_t}
    dom = max(terms, key=terms.get)
    return {
        **terms,
        "dominant": dom.replace("_s", ""),
        "hlo_flops_per_device": dot_flops_dev,
        "hlo_flops_cluster": dot_flops_dev * n_chips,
        "analytic_flops_cluster": analytic_flops,
        "analytic_bytes_cluster": analytic_bytes,
        "model_flops": model_flops,
        "useful_fraction": (model_flops / (dot_flops_dev * n_chips)
                            if dot_flops_dev else None),
        "collective_breakdown": hlo["collective_breakdown"],
    }


def serving_weight_mode(cfg: ModelConfig) -> str:
    """Optimized serving layout (§Perf): replicate small models, TP-only
    mid-size, keep FSDP for MoE (expert tensors dominate; TP-only layouts
    inflate dispatch temps 15x with no collective win — measured on
    granite) and for models whose TP-16 shard exceeds ~8 GB/chip."""
    if cfg.is_moe:
        return "fsdp_tp"
    bytes_tp16 = 2 * cfg.num_params() / 16
    if 2 * cfg.num_params() < 6e9:
        return "replicated"
    if bytes_tp16 < 8e9:
        return "tp_only"
    return "fsdp_tp"


def run_all(multi_pod: bool, out_path: str | None, archs=None, shapes=None,
            optimized: bool = False):
    results = []
    for arch in (archs or ASSIGNED):
        for shape_name in (shapes or list(INPUT_SHAPES)):
            reason = skip_reason(arch, shape_name)
            tag = f"{arch} x {shape_name} [{'2x16x16' if multi_pod else '16x16'}]"
            if reason:
                print(f"SKIP {tag}: {reason}", flush=True)
                results.append({"arch": arch, "shape": shape_name,
                                "mesh": "2x16x16" if multi_pod else "16x16",
                                "skipped": reason})
                continue
            kw = {}
            if optimized and INPUT_SHAPES[shape_name].kind != "train":
                kw["weight_mode"] = serving_weight_mode(get_config(arch))
            if not optimized:
                kw["seq_attn"] = False  # paper-faithful baseline sharding
            try:
                res, _, compiled = lower_case(arch, shape_name,
                                              multi_pod=multi_pod, **kw)
                m = res["memory"]
                print(f"OK   {tag}: compile {res['compile_s']}s  "
                      f"temp/dev {(m['temp_bytes'] or 0)/1e9:.2f} GB  "
                      f"args/dev {(m['argument_bytes'] or 0)/1e9:.2f} GB  "
                      f"dominant={res['roofline']['dominant']}", flush=True)
                results.append(res)
                del compiled
            except Exception as e:  # a failure here is a sharding bug
                print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
                traceback.print_exc()
                results.append({"arch": arch, "shape": shape_name,
                                "mesh": "2x16x16" if multi_pod else "16x16",
                                "error": f"{type(e).__name__}: {e}"})
    if out_path:
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {out_path}")
    n_fail = sum(1 for r in results if "error" in r)
    print(f"\n{len(results)} cases: {n_fail} failures")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--weight-mode", default=None,
                    choices=["fsdp_tp", "tp_only", "replicated"])
    ap.add_argument("--optimized", action="store_true",
                    help="beyond-paper sharding (seq-attn auto + serving "
                         "weight layouts); default is the recorded baseline")
    args = ap.parse_args()
    if args.all:
        run_all(args.multi_pod, args.out,
                archs=[args.arch] if args.arch else None,
                shapes=[args.shape] if args.shape else None,
                optimized=args.optimized)
        return
    assert args.arch and args.shape, "--arch and --shape (or --all)"
    res, lowered, compiled = lower_case(args.arch, args.shape,
                                        multi_pod=args.multi_pod,
                                        weight_mode=args.weight_mode)
    print(compiled.memory_analysis())
    print({k: v for k, v in (compiled.cost_analysis() or {}).items()
           if k in ("flops", "bytes accessed")})
    print(json.dumps(res["roofline"], indent=1, default=float))


if __name__ == "__main__":
    main()
