"""Zamba2 hybrid: stacked Mamba2 layers with a single *shared* attention
block applied every ``shared_attn_every`` layers (arXiv:2411.15242).

The shared block's weights are one parameter set; each of its application
points keeps its own KV cache. Mamba layers are scanned in groups between
attention applications (81 = 13 groups of 6 + trailing 3 by default).

Decode state: per-mamba-layer (conv, ssm) states — O(1) in sequence — plus
the shared-attn KV caches, which in long-context mode are windowed
(DESIGN.md §4), so the arch runs long_500k.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention, layers, mamba2
from repro.models.config import ModelConfig
from repro.models.transformer import block_decode, block_prefill, init_block
from repro.sharding.ctx import constrain


class Zamba2Model:
    def __init__(self, cfg: ModelConfig, *, impl: str = "xla",
                 long_context: bool = False, remat: bool = True, **_):
        assert cfg.shared_attn_every > 0
        self.cfg = cfg
        self.impl = impl
        self.long_context = long_context
        self.remat = remat
        g = cfg.shared_attn_every
        self.n_full_groups = cfg.num_layers // g
        self.trailing = cfg.num_layers - self.n_full_groups * g
        self.n_attn = self.n_full_groups  # one shared-attn application per full group

    def _attn_window(self) -> int:
        # full attention normally; windowed in long-context mode (DESIGN §4)
        return (self.cfg.global_window_long or 32768) if self.long_context else 0

    def _attn_cache_size(self, seq_len: int) -> int:
        w = self._attn_window()
        return min(w, seq_len) if w else seq_len

    # --- params -----------------------------------------------------------
    def init(self, rng):
        cfg = self.cfg
        ke, km, ka, kh = jax.random.split(rng, 4)
        mp = jax.vmap(lambda r: self._init_mamba_layer(r))(
            jax.random.split(km, cfg.num_layers))
        return {
            "embed": (jax.random.normal(ke, (cfg.vocab_size, cfg.d_model),
                                        jnp.float32) * 0.02).astype(cfg.jnp_dtype),
            "mamba_layers": mp,
            "shared_attn": init_block(ka, cfg),  # one block, reused at 13 points
            "final_norm": layers.init_rmsnorm(cfg.d_model, cfg.jnp_dtype),
            "lm_head": (jax.random.normal(kh, (cfg.d_model, cfg.vocab_size),
                                          jnp.float32) * 0.02).astype(cfg.jnp_dtype),
        }

    def _init_mamba_layer(self, rng):
        return {
            "ln": layers.init_rmsnorm(self.cfg.d_model, self.cfg.jnp_dtype),
            "mamba": mamba2.init_mamba(rng, self.cfg),
        }

    def _slice_layers(self, params, start, size):
        return jax.tree.map(lambda a: jax.lax.slice_in_dim(a, start, start + size),
                            params["mamba_layers"])

    # --- cache ------------------------------------------------------------
    def init_cache(self, batch_size: int, cache_len: int = 0, prefilled_len: int = 0):
        cfg = self.cfg
        L = cfg.num_layers
        conv_dim = cfg.ssm_d_inner + 2 * cfg.ssm_state
        C = self._attn_cache_size(max(cache_len, 1))
        return {
            "conv": jnp.zeros((L, batch_size, cfg.ssm_conv - 1, conv_dim), cfg.jnp_dtype),
            "ssm": jnp.zeros((L, batch_size, cfg.ssm_nheads, cfg.ssm_head_dim,
                              cfg.ssm_state), jnp.float32),
            "attn_k": jnp.zeros((self.n_attn, batch_size, C, cfg.num_kv_heads,
                                 cfg.head_dim_), cfg.jnp_dtype),
            "attn_v": jnp.zeros((self.n_attn, batch_size, C, cfg.num_kv_heads,
                                 cfg.head_dim_), cfg.jnp_dtype),
            "pos": jnp.full((batch_size,), prefilled_len, jnp.int32),
        }

    # --- forward ----------------------------------------------------------
    def _mamba_group_prefill(self, lp, x, conv0, ssm0):
        cfg = self.cfg

        def body(x, inp):
            x = constrain(x, "act_btd")
            lp_i, conv, ssm = inp
            h = layers.rmsnorm(lp_i["ln"], x, cfg.norm_eps)
            out, (conv, ssm) = mamba2.mamba_prefill(lp_i["mamba"], h, cfg, conv, ssm)
            return x + out, (conv, ssm)

        if self.remat:
            body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        x, (conv, ssm) = jax.lax.scan(body, x, (lp, conv0, ssm0))
        return x, conv, ssm

    def _mamba_group_decode(self, lp, x, conv0, ssm0):
        cfg = self.cfg

        def body(x, inp):
            lp_i, conv, ssm = inp
            h = layers.rmsnorm(lp_i["ln"], x, cfg.norm_eps)
            out, (conv, ssm) = mamba2.mamba_decode(lp_i["mamba"], h, cfg, conv, ssm)
            return x + out, (conv, ssm)

        return jax.lax.scan(body, x, (lp, conv0, ssm0))

    def _groups(self):
        g = self.cfg.shared_attn_every
        out = [(i * g, g, True) for i in range(self.n_full_groups)]
        if self.trailing:
            out.append((self.n_full_groups * g, self.trailing, False))
        return out  # (start, size, followed_by_attn)

    def prefill(self, params, batch, cache_len: int = 0):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        cache_len = cache_len or S
        x = constrain(params["embed"][tokens], "act_btd")
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        lens = batch.get("lengths")
        if lens is None:
            lens = jnp.full((B,), S, jnp.int32)

        convs, ssms, aks, avs = [], [], [], []
        window = self._attn_window()
        C = self._attn_cache_size(cache_len)
        sab = params["shared_attn"]
        for (start, size, with_attn) in self._groups():
            lp = self._slice_layers(params, start, size)
            conv0 = jnp.zeros((size, B, cfg.ssm_conv - 1,
                               cfg.ssm_d_inner + 2 * cfg.ssm_state), x.dtype)
            ssm0 = jnp.zeros((size, B, cfg.ssm_nheads, cfg.ssm_head_dim,
                              cfg.ssm_state), jnp.float32)
            x, conv, ssm = self._mamba_group_prefill(lp, x, conv0, ssm0)
            convs.append(conv)
            ssms.append(ssm)
            if with_attn:
                x, kv, _ = block_prefill(sab, x, positions, cfg, window=window,
                                         kv_lens=lens, cache_len=C, impl=self.impl)
                aks.append(kv[0])
                avs.append(kv[1])

        last = jnp.take_along_axis(x, (lens - 1)[:, None, None], axis=1)[:, 0]
        logits = self._logits(params, last)
        cache = {
            "conv": jnp.concatenate(convs, axis=0).astype(cfg.jnp_dtype),
            "ssm": jnp.concatenate(ssms, axis=0),
            "attn_k": jnp.stack(aks, axis=0),
            "attn_v": jnp.stack(avs, axis=0),
            "pos": lens.astype(jnp.int32),
        }
        return logits, cache

    def decode_step(self, params, tokens, cache):
        cfg = self.cfg
        x = params["embed"][tokens[:, None]]
        pos = cache["pos"]
        lengths = pos + 1
        convs, ssms, aks, avs = [], [], [], []
        sab = params["shared_attn"]
        ai = 0
        for (start, size, with_attn) in self._groups():
            lp = self._slice_layers(params, start, size)
            conv0 = jax.lax.slice_in_dim(cache["conv"], start, start + size)
            ssm0 = jax.lax.slice_in_dim(cache["ssm"], start, start + size)
            x, (conv, ssm) = self._mamba_group_decode(lp, x, conv0, ssm0)
            convs.append(conv)
            ssms.append(ssm)
            if with_attn:
                ck, cv = cache["attn_k"][ai], cache["attn_v"][ai]
                x, ck, cv = block_decode(sab, x, pos, cfg, ck, cv, lengths,
                                         impl=self.impl)
                aks.append(ck)
                avs.append(cv)
                ai += 1
        logits = self._logits(params, x[:, 0])
        new_cache = {
            "conv": jnp.concatenate(convs, axis=0),
            "ssm": jnp.concatenate(ssms, axis=0),
            "attn_k": jnp.stack(aks, axis=0),
            "attn_v": jnp.stack(avs, axis=0),
            "pos": pos + 1,
        }
        return logits, new_cache

    def _logits(self, params, x):
        x = layers.rmsnorm(params["final_norm"], x, self.cfg.norm_eps)
        return (x @ params["lm_head"]).astype(jnp.float32)

    def loss(self, params, batch):
        tokens = batch["tokens"]
        B, S = tokens.shape
        cfg = self.cfg
        x = constrain(params["embed"][tokens], "act_btd")
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        lens = jnp.full((B,), S, jnp.int32)
        sab = params["shared_attn"]
        for (start, size, with_attn) in self._groups():
            lp = self._slice_layers(params, start, size)
            conv0 = jnp.zeros((size, B, cfg.ssm_conv - 1,
                               cfg.ssm_d_inner + 2 * cfg.ssm_state), x.dtype)
            ssm0 = jnp.zeros((size, B, cfg.ssm_nheads, cfg.ssm_head_dim,
                              cfg.ssm_state), jnp.float32)
            x, _, _ = self._mamba_group_prefill(lp, x, conv0, ssm0)
            if with_attn:
                impl = "xla_naive" if (self.impl == "xla" and S <= 8192) else self.impl
                x, _, _ = block_prefill(sab, x, positions, cfg, window=0,
                                        kv_lens=lens, cache_len=0, impl=impl)
        x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = (x @ params["lm_head"]).astype(jnp.float32)
        return layers.cross_entropy_loss(logits, batch["labels"])
