"""Shared neural-net building blocks (functional, params-as-pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def init_dense(rng, d_in: int, d_out: int, dtype, scale: float = 0.02, bias: bool = False):
    w = jax.random.normal(rng, (d_in, d_out), jnp.float32) * scale
    p = {"w": w.astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype):
    return {"scale": jnp.zeros((d,), dtype)}  # gemma-style (1 + scale)


def rmsnorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + p["scale"].astype(jnp.float32))).astype(dt)


def init_layernorm(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., seq, hd/2)
    ang = ang[..., None, :]  # broadcast over heads
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(num_pos: int, d_model: int) -> np.ndarray:
    """Whisper-style sinusoidal embeddings."""
    log_timescale = np.log(10000.0) / (d_model // 2 - 1)
    inv = np.exp(-log_timescale * np.arange(d_model // 2))
    t = np.arange(num_pos)[:, None] * inv[None, :]
    return np.concatenate([np.sin(t), np.cos(t)], axis=1).astype(np.float32)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(rng, d_model: int, d_ff: int, dtype, act: str = "silu"):
    ks = jax.random.split(rng, 3)
    if act == "gelu_mlp":  # plain 2-matrix MLP (whisper)
        return {
            "up": init_dense(ks[0], d_model, d_ff, dtype, bias=True),
            "down": init_dense(ks[1], d_ff, d_model, dtype, bias=True),
        }
    return {  # gated (swiglu / geglu)
        "gate": init_dense(ks[0], d_model, d_ff, dtype),
        "up": init_dense(ks[1], d_model, d_ff, dtype),
        "down": init_dense(ks[2], d_ff, d_model, dtype, scale=0.02 / np.sqrt(2)),
    }


def mlp(p, x, act: str = "silu"):
    if "gate" not in p:
        return dense(p["down"], jax.nn.gelu(dense(p["up"], x)))
    a = dense(p["gate"], x)
    a = jax.nn.silu(a) if act == "silu" else jax.nn.gelu(a)
    return dense(p["down"], a * dense(p["up"], x))


def softcap(x, cap: float):
    return jnp.tanh(x / cap) * cap if cap else x


def cross_entropy_loss(logits, labels, mask=None):
    """logits (..., V) any dtype; computed in f32. labels int32, -1 = ignore."""
    logits = logits.astype(jnp.float32)
    valid = (labels >= 0) if mask is None else mask
    labels = jnp.maximum(labels, 0)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0] - logz
    valid = valid.astype(jnp.float32)
    return -(ll * valid).sum() / jnp.maximum(valid.sum(), 1.0)
