"""Whisper-style encoder-decoder (arXiv:2212.04356).

Per the assignment carve-out, the mel-spectrogram + conv feature extractor is
a STUB: ``input_specs()`` provides precomputed frame embeddings (B, T, d) and
this module implements the transformer backbone that consumes them —
bidirectional encoder + causal decoder with cross-attention.

Serving mapping (DESIGN §4): the encoder pass plays the role of Prefill
(latency-relaxed pool), the decoder loop the role of Decode — OOCO scheduling
applies unchanged.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention, layers
from repro.sharding.ctx import constrain
from repro.models.config import ModelConfig


def _ln(cfg):
    return layers.init_layernorm(cfg.d_model, cfg.jnp_dtype)


def init_enc_block(rng, cfg: ModelConfig):
    k1, k2 = jax.random.split(rng)
    return {
        "ln1": _ln(cfg), "attn": attention.init_attn(k1, cfg),
        "ln2": _ln(cfg),
        "mlp": layers.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.jnp_dtype, "gelu_mlp"),
    }


def init_dec_block(rng, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "ln1": _ln(cfg), "self_attn": attention.init_attn(k1, cfg),
        "ln2": _ln(cfg), "cross_attn": attention.init_attn(k2, cfg),
        "ln3": _ln(cfg),
        "mlp": layers.init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.jnp_dtype, "gelu_mlp"),
    }


class WhisperModel:
    def __init__(self, cfg: ModelConfig, *, impl: str = "xla", remat: bool = True, **_):
        self.cfg = cfg
        self.impl = impl
        self.remat = remat

    def init(self, rng):
        cfg = self.cfg
        ke, kenc, kdec = jax.random.split(rng, 3)
        enc = jax.vmap(lambda r: init_enc_block(r, cfg))(
            jax.random.split(kenc, cfg.encoder_layers))
        dec = jax.vmap(lambda r: init_dec_block(r, cfg))(
            jax.random.split(kdec, cfg.num_layers))
        return {
            "embed": (jax.random.normal(ke, (cfg.vocab_size, cfg.d_model),
                                        jnp.float32) * 0.02).astype(cfg.jnp_dtype),
            "enc_layers": enc,
            "dec_layers": dec,
            "enc_norm": _ln(cfg),
            "dec_norm": _ln(cfg),
        }

    # --- encoder (≈ Prefill in OOCO terms) ---------------------------------
    def encode(self, params, frames, frame_lens=None, impl: str | None = None):
        """frames: (B, T, d) precomputed embeddings (frontend stub)."""
        cfg = self.cfg
        impl = impl or self.impl
        B, T, _ = frames.shape
        pos = jnp.asarray(layers.sinusoidal_positions(T, cfg.d_model),
                          frames.dtype)
        x = constrain(frames + pos[None], "act_btd")
        positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
        if frame_lens is None:
            frame_lens = jnp.full((B,), T, jnp.int32)

        def body(x, lp):
            x = constrain(x, "act_btd")
            h = layers.layernorm(lp["ln1"], x, cfg.norm_eps)
            a, _ = attention.attn_prefill(lp["attn"], h, positions, cfg,
                                          causal=False, kv_lens=frame_lens,
                                          impl=impl)
            x = x + a
            h = layers.layernorm(lp["ln2"], x, cfg.norm_eps)
            return x + layers.mlp(lp["mlp"], h, "gelu_mlp"), None

        if self.remat:
            body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
        return layers.layernorm(params["enc_norm"], x, cfg.norm_eps)

    # --- decoder -----------------------------------------------------------
    def _cross_kv(self, lp, enc_out):
        """Project encoder output to per-layer cross K/V (cached once)."""
        B, T, _ = enc_out.shape
        cfg = self.cfg
        hd = cfg.head_dim_
        k = layers.dense(lp["cross_attn"]["wk"], enc_out).reshape(B, T, cfg.num_kv_heads, hd)
        v = layers.dense(lp["cross_attn"]["wv"], enc_out).reshape(B, T, cfg.num_kv_heads, hd)
        return k, v

    def _dec_forward(self, params, x, positions, tok_lens, enc_out, enc_lens,
                     cache_len: int, impl: str | None = None):
        cfg = self.cfg
        impl = impl or self.impl

        def body(carry, lp):
            x = constrain(carry, "act_btd")
            h = layers.layernorm(lp["ln1"], x, cfg.norm_eps)
            a, kv = attention.attn_prefill(lp["self_attn"], h, positions, cfg,
                                           kv_lens=tok_lens, impl=impl)
            x = x + a
            h = layers.layernorm(lp["ln2"], x, cfg.norm_eps)
            ck, cv = self._cross_kv(lp, enc_out)
            a, _ = attention.attn_prefill(lp["cross_attn"], h, positions, cfg,
                                          cross_kv=(ck, cv), kv_lens=enc_lens,
                                          impl=impl)
            x = x + a
            h = layers.layernorm(lp["ln3"], x, cfg.norm_eps)
            x = x + layers.mlp(lp["mlp"], h, "gelu_mlp")
            out = None
            if cache_len:
                sk, sv = attention.write_prefill_cache(kv[0], kv[1], cache_len)
                out = (sk, sv, ck, cv)
            return x, out

        if self.remat:
            body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        return jax.lax.scan(body, x, params["dec_layers"])

    def prefill(self, params, batch, cache_len: int = 0):
        """batch: frontend_embeds (B,T,d) audio frames, tokens (B,S) decoder
        prompt, [lengths (B,)]. Returns (last-token logits, cache)."""
        cfg = self.cfg
        frames = batch["frontend_embeds"]
        tokens = batch["tokens"]
        B, S = tokens.shape
        enc_lens = batch.get("frame_lens")
        enc_out = self.encode(params, frames, enc_lens)
        if enc_lens is None:
            enc_lens = jnp.full((B,), frames.shape[1], jnp.int32)
        tok_lens = batch.get("lengths")
        if tok_lens is None:
            tok_lens = jnp.full((B,), S, jnp.int32)

        pos_emb = jnp.asarray(layers.sinusoidal_positions(S, cfg.d_model), cfg.jnp_dtype)
        x = params["embed"][tokens] + pos_emb[None]
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x, caches = self._dec_forward(params, x, positions, tok_lens, enc_out,
                                      enc_lens, cache_len or S)
        last = jnp.take_along_axis(x, (tok_lens - 1)[:, None, None], axis=1)[:, 0]
        logits = self._logits(params, last)
        sk, sv, ck, cv = caches
        cache = {"self_k": sk, "self_v": sv, "cross_k": ck, "cross_v": cv,
                 "enc_lens": enc_lens, "pos": tok_lens.astype(jnp.int32)}
        return logits, cache

    def init_cache(self, batch_size: int, cache_len: int, prefilled_len: int = 0,
                   enc_len: int = 1500):
        cfg = self.cfg
        hd = cfg.head_dim_
        L = cfg.num_layers
        z = lambda T: jnp.zeros((L, batch_size, T, cfg.num_kv_heads, hd), cfg.jnp_dtype)
        return {"self_k": z(cache_len), "self_v": z(cache_len),
                "cross_k": z(enc_len), "cross_v": z(enc_len),
                "enc_lens": jnp.full((batch_size,), enc_len, jnp.int32),
                "pos": jnp.full((batch_size,), prefilled_len, jnp.int32)}

    def decode_step(self, params, tokens, cache):
        cfg = self.cfg
        B = tokens.shape[0]
        pos = cache["pos"]
        lengths = pos + 1
        # sinusoidal position of the current token, gathered per request
        max_pos = cache["self_k"].shape[2] + 1  # cache is not windowed; pos <= C
        table = jnp.asarray(layers.sinusoidal_positions(max_pos, cfg.d_model), cfg.jnp_dtype)
        x = params["embed"][tokens[:, None]] + table[jnp.minimum(pos, max_pos - 1)][:, None]

        def body(x, inp):
            lp, sk, sv, ck, cv = inp
            h = layers.layernorm(lp["ln1"], x, cfg.norm_eps)
            k_new, v_new = attention.project_kv_for_cache(lp["self_attn"], h, pos, cfg)
            sk, sv = attention.write_decode_cache(sk, sv, k_new, v_new, pos)
            a = attention.attn_decode(lp["self_attn"], h, sk, sv, pos, lengths,
                                      cfg, impl=self.impl)
            x = x + a
            h = layers.layernorm(lp["ln2"], x, cfg.norm_eps)
            hd_ = cfg.head_dim_
            q = layers.dense(lp["cross_attn"]["wq"], h).reshape(B, cfg.num_heads, hd_)
            a = attention.decode_attention_xla(q, ck, cv, cache["enc_lens"])
            a = layers.dense(lp["cross_attn"]["wo"], a.reshape(B, 1, -1))
            x = x + a
            h = layers.layernorm(lp["ln3"], x, cfg.norm_eps)
            return x + layers.mlp(lp["mlp"], h, "gelu_mlp"), (sk, sv)

        xs = (params["dec_layers"], cache["self_k"], cache["self_v"],
              cache["cross_k"], cache["cross_v"])
        x, (sk, sv) = jax.lax.scan(body, x, xs)
        logits = self._logits(params, x[:, 0])
        new_cache = dict(cache, self_k=sk, self_v=sv, pos=pos + 1)
        return logits, new_cache

    def _logits(self, params, x):
        x = layers.layernorm(params["dec_norm"], x, self.cfg.norm_eps)
        return (x @ params["embed"].T).astype(jnp.float32)  # tied head

    def loss(self, params, batch):
        """batch: frontend_embeds, tokens, labels."""
        cfg = self.cfg
        frames = batch["frontend_embeds"]
        impl = ("xla_naive" if self.impl == "xla" and frames.shape[1] <= 8192
                else self.impl)
        enc_out = self.encode(params, frames, impl=impl)
        tokens = batch["tokens"]
        B, S = tokens.shape
        enc_lens = jnp.full((B,), enc_out.shape[1], jnp.int32)
        tok_lens = jnp.full((B,), S, jnp.int32)
        pos_emb = jnp.asarray(layers.sinusoidal_positions(S, cfg.d_model), cfg.jnp_dtype)
        x = params["embed"][tokens] + pos_emb[None]
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x, _ = self._dec_forward(params, x, positions, tok_lens, enc_out,
                                 enc_lens, 0, impl=impl)
        x = layers.layernorm(params["dec_norm"], x, cfg.norm_eps)
        logits = (x @ params["embed"].T).astype(jnp.float32)
        return layers.cross_entropy_loss(logits, batch["labels"])
