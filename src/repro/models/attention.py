"""Attention: flash-style blockwise XLA implementation + decode-with-cache.

The XLA path is the default everywhere (it lowers on any backend and is what
the multi-pod dry-run compiles). The Pallas kernels in ``repro.kernels``
implement the same block structure with explicit VMEM BlockSpecs for the TPU
target and are validated against ``repro.kernels.*.ref`` in interpret mode.

Supports: GQA, sliding windows (ring-buffer caches), gemma2 logit softcap,
qwen3 qk-norm, qwen2.5 QKV bias.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models.config import ModelConfig
from repro.sharding.ctx import constrain

NEG_INF = -1e30

#: attention-backend name (repro.kernels.resolve_backend) -> attn_prefill impl
IMPL_FOR_BACKEND = {"pallas": "pallas", "interpret": "pallas_interpret",
                    "ref": "xla"}


def impl_for_backend(backend: str) -> str:
    """Map an engine attention backend to the ``attn_prefill`` impl name.

    ``"ref"`` maps to the pure-XLA flash path (the CPU oracle the Pallas
    kernels are validated against), not the naive full-score path."""
    from repro.kernels import resolve_backend
    return IMPL_FOR_BACKEND[resolve_backend(backend)]


def init_attn(rng, cfg: ModelConfig, d_model: int | None = None):
    d = d_model or cfg.d_model
    hd = cfg.head_dim_
    ks = jax.random.split(rng, 4)
    dt = cfg.jnp_dtype
    p = {
        "wq": layers.init_dense(ks[0], d, cfg.num_heads * hd, dt, bias=cfg.attn_bias),
        "wk": layers.init_dense(ks[1], d, cfg.num_kv_heads * hd, dt, bias=cfg.attn_bias),
        "wv": layers.init_dense(ks[2], d, cfg.num_kv_heads * hd, dt, bias=cfg.attn_bias),
        "wo": layers.init_dense(ks[3], cfg.num_heads * hd, d, dt,
                                scale=0.02 / np.sqrt(2 * max(cfg.num_layers, 1))),
    }
    if cfg.qk_norm:
        p["q_norm"] = layers.init_rmsnorm(hd, dt)
        p["k_norm"] = layers.init_rmsnorm(hd, dt)
    return p


def _project_qkv(p, x, cfg: ModelConfig, positions, rope: bool = True):
    """x (B,S,d); positions (B,S). Returns q (B,S,Hq,hd), k/v (B,S,Hkv,hd)."""
    B, S, _ = x.shape
    hd = cfg.head_dim_
    q = layers.dense(p["wq"], x).reshape(B, S, cfg.num_heads, hd)
    k = layers.dense(p["wk"], x).reshape(B, S, cfg.num_kv_heads, hd)
    v = layers.dense(p["wv"], x).reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = layers.rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = layers.rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if rope:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# Blockwise ("flash") attention, pure XLA
# ---------------------------------------------------------------------------

class _Carry(NamedTuple):
    m: jax.Array    # (B, Hkv, G, Sq) running max, f32
    l: jax.Array    # (B, Hkv, G, Sq) running denominator, f32
    acc: jax.Array  # (B, Hkv, G, Sq, hd) running numerator, f32


def flash_attention_xla(
    q, k, v, *,
    causal: bool = True,
    window: int = 0,
    q_offset=0,
    kv_lens=None,
    logit_softcap: float = 0.0,
    kv_block: int = 512,
    scale: float | None = None,
):
    """Online-softmax attention over KV blocks; never materializes (Sq, Skv).

    q: (B, Sq, Hq, hd); k, v: (B, Skv, Hkv, hd) with Hq % Hkv == 0 (GQA).
    q_offset: scalar or (B,) absolute position of q[;, 0] (prefill chunking /
    decode). kv_lens: (B,) valid KV length (padding mask). window: 0 = full.
    Returns (B, Sq, Hq, hd) in q.dtype.
    """
    B, Sq, Hq, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)

    nblk = -(-Skv // kv_block)
    pad = nblk * kv_block - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    if kv_lens is None:
        kv_lens = jnp.full((B,), Skv, jnp.int32)

    qg = q.reshape(B, Sq, Hkv, G, hd)
    q_pos = jnp.asarray(q_offset)[..., None] + jnp.arange(Sq)  # (B?, Sq)
    q_pos = jnp.broadcast_to(q_pos, (B, Sq))

    kb = k.reshape(B, nblk, kv_block, Hkv, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblk, kv_block, Hkv, hd).transpose(1, 0, 2, 3, 4)

    def body(carry: _Carry, blk):
        kblk, vblk, blk_idx = blk  # (B, kv_block, Hkv, hd)
        s = jnp.einsum("bqkgd,bckd->bkgqc", qg.astype(jnp.float32),
                       kblk.astype(jnp.float32)) * scale
        if logit_softcap:
            s = layers.softcap(s, logit_softcap)
        k_pos = blk_idx * kv_block + jnp.arange(kv_block)  # (kv_block,)
        valid = k_pos[None, :] < kv_lens[:, None]  # (B, c)
        mask = valid[:, None, None, None, :]
        if causal:
            rel = q_pos[:, :, None] - k_pos[None, None, :]  # (B, Sq, c)
            cm = rel >= 0
            if window:
                cm &= rel < window
            mask = mask & cm[:, None, None, :, :]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(carry.m, s.max(axis=-1))
        alpha = jnp.exp(carry.m - m_new)
        p_ = jnp.exp(s - m_new[..., None])
        l_new = carry.l * alpha + p_.sum(axis=-1)
        acc = carry.acc * alpha[..., None] + jnp.einsum(
            "bkgqc,bckd->bkgqd", p_, vblk.astype(jnp.float32))
        return _Carry(m_new, l_new, acc), None

    init = _Carry(
        m=jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32),
        l=jnp.zeros((B, Hkv, G, Sq), jnp.float32),
        acc=jnp.zeros((B, Hkv, G, Sq, hd), jnp.float32),
    )
    carry, _ = jax.lax.scan(body, init, (kb, vb, jnp.arange(nblk)))
    out = carry.acc / jnp.maximum(carry.l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, hd)
    return out.astype(q.dtype)


def naive_attention_xla(q, k, v, *, causal=True, window: int = 0, kv_lens=None,
                        logit_softcap: float = 0.0, scale=None):
    """Full-score attention (materializes (Sq, Skv)). Used for *training* at
    moderate sequence lengths: XLA's backward through the flash scan saves
    per-block softmax intermediates (O(nblocks * Sq * block) — worse than the
    full score matrix at 4k), while the naive path keeps exactly one score
    tensor. Serving prefill (no grad) uses the flash path."""
    B, Sq, Hq, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    qg = q.reshape(B, Sq, Hkv, G, hd)
    s = jnp.einsum("bqkgd,bckd->bkgqc", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if logit_softcap:
        s = layers.softcap(s, logit_softcap)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        rel = q_pos - k_pos
        mask = rel >= 0
        if window:
            mask &= rel < window
    mask = jnp.broadcast_to(mask[None], (B, Sq, Skv))
    if kv_lens is not None:
        mask = mask & (k_pos[None] < kv_lens[:, None, None])
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqc,bckd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, hd).astype(q.dtype)


def decode_attention_xla(q, k_cache, v_cache, lengths, *,
                         logit_softcap: float = 0.0, scale: float | None = None):
    """Single-token decode attention over a (possibly ring-buffer) cache.

    q: (B, Hq, hd); caches: (B, C, Hkv, hd); lengths: (B,) tokens written so
    far (including the current one). Valid slots = min(lengths, C) — with a
    ring buffer the whole cache is valid once wrapped, and softmax order-
    invariance makes slot permutation irrelevant.
    """
    B, Hq, hd = q.shape
    C, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    qg = q.reshape(B, Hkv, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,bckd->bkgc", qg, k_cache.astype(jnp.float32)) * scale
    if logit_softcap:
        s = layers.softcap(s, logit_softcap)
    valid = jnp.arange(C)[None, :] < jnp.minimum(lengths, C)[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgc,bckd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, Hq, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full attention sub-layer (projections + rope + core + out-proj)
# ---------------------------------------------------------------------------

def attn_prefill(p, x, positions, cfg: ModelConfig, *, window: int = 0,
                 causal: bool = True, kv_lens=None, impl: str = "xla",
                 cross_kv=None):
    """Returns (out (B,S,d), (k, v) post-rope for caching)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, positions, rope=not cfg.is_encoder_decoder)
    if cross_kv is not None:
        k, v = cross_kv
        causal = False
    # sequence-sharded attention for head counts that do not divide the TP
    # axis (40H/8H/24H/6H vs 16-wide "model"): without this, GSPMD re-reduces
    # score tensors inside every flash kv-block step (observed 2.9 TB/dev of
    # all-reduce on qwen2.5-32b prefill_32k — EXPERIMENTS.md §Perf). The
    # launcher activates these keys only for non-divisible-head archs.
    q = constrain(q, "attn_q_seq")
    k = constrain(k, "attn_kv_rep")
    v = constrain(v, "attn_kv_rep")
    if impl == "pallas" or impl == "pallas_interpret":
        from repro.kernels.flash_prefill import ops as fp_ops
        out = fp_ops.flash_attention(
            q, k, v, causal=causal, window=window,
            logit_softcap=cfg.attn_logit_softcap, kv_lens=kv_lens,
            interpret=(impl == "pallas_interpret"))
    elif impl == "xla_naive":
        out = naive_attention_xla(
            q, k, v, causal=causal, window=window, kv_lens=kv_lens,
            logit_softcap=cfg.attn_logit_softcap)
    else:
        out = flash_attention_xla(
            q, k, v, causal=causal, window=window, kv_lens=kv_lens,
            logit_softcap=cfg.attn_logit_softcap)
    # Optional Megatron-SP reshard before the output projection. Measured on
    # qwen2.5-32b prefill_32k it REGRESSED 5.16s -> 6.29s of collectives:
    # the per-layer weight gathers it avoids (~1 GB/layer) are cheaper than
    # the activation all-reduces it introduces (~2.7 GB/layer) at this B*S.
    # Kept opt-in for smaller-batch regimes (EXPERIMENTS.md §Perf, refuted).
    out = constrain(out, "attn_out_rep")
    out = layers.dense(p["wo"], out.reshape(B, S, -1))
    return out, (k, v)


def attn_decode(p, x, cache_k, cache_v, positions, lengths, cfg: ModelConfig,
                *, impl: str = "xla"):
    """x (B,1,d); caches (B,C,Hkv,hd) ALREADY containing the current token's
    k/v (caller writes before calling, so cache layout stays caller-owned).
    positions (B,) absolute position of the current token.
    """
    B = x.shape[0]
    q, _, _ = _project_qkv(p, x, cfg, positions[:, None], rope=not cfg.is_encoder_decoder)
    q = q[:, 0]  # (B, Hq, hd)
    # decode-side analogue: with non-divisible heads, keep q replicated over
    # "model" so the attention over the seq-sharded cache stays local + a
    # small partial-softmax all-reduce (instead of gathering the cache)
    q = constrain(q, "attn_q_dec")
    out = decode_attention_xla(q, cache_k, cache_v, lengths,
                               logit_softcap=cfg.attn_logit_softcap)
    return layers.dense(p["wo"], out.reshape(B, 1, -1))


def project_kv_for_cache(p, x, positions, cfg: ModelConfig):
    """k, v (post-rope) for the current decode token: (B, 1, Hkv, hd)."""
    _, k, v = _project_qkv(p, x, cfg, positions[:, None], rope=not cfg.is_encoder_decoder)
    return k, v


def write_decode_cache(cache_k, cache_v, k_new, v_new, positions):
    """Scatter one token per request into a (ring-buffer) cache.

    caches (B,C,Hkv,hd); k_new/v_new (B,1,Hkv,hd); positions (B,) absolute.
    Slot = position % C (ring buffer ≡ plain cache when C >= max_seq).
    """
    B, C = cache_k.shape[0], cache_k.shape[1]
    slot = (positions % C).astype(jnp.int32)
    idx = jnp.arange(B)
    cache_k = cache_k.at[idx, slot].set(k_new[:, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[idx, slot].set(v_new[:, 0].astype(cache_v.dtype))
    return cache_k, cache_v


def write_prefill_cache(k, v, cache_size: int, dtype=None):
    """Build a decode cache from prefill K/V (B,S,Hkv,hd), keeping the last
    ``cache_size`` tokens at ring slots pos %% cache_size."""
    B, S, Hkv, hd = k.shape
    dtype = dtype or k.dtype
    if S <= cache_size:
        pad = cache_size - S
        ck = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(dtype)
        cv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(dtype)
        return ck, cv
    # keep last cache_size tokens; place token at absolute pos p in slot p % C
    tail_k, tail_v = k[:, -cache_size:], v[:, -cache_size:]
    start = S - cache_size
    slots = (start + jnp.arange(cache_size)) % cache_size
    order = jnp.argsort(slots)
    return tail_k[:, order].astype(dtype), tail_v[:, order].astype(dtype)
