"""Model configuration system.

Every architecture in the zoo is *data*: a single frozen dataclass that the
generic model builders consume. One config module per assigned architecture
lives in ``repro/configs/``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax.numpy as jnp

# Families
DENSE = "dense"
MOE = "moe"
SSM = "ssm"  # rwkv6
HYBRID = "hybrid"  # zamba2: mamba2 + shared attention
VLM = "vlm"
AUDIO = "audio"  # whisper enc-dec

FAMILIES = (DENSE, MOE, SSM, HYBRID, VLM, AUDIO)


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters. Only fields relevant to the family are used."""

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- attention variants ---
    attn_bias: bool = False           # qwen2.5: bias on QKV projections
    qk_norm: bool = False             # qwen3: per-head RMSNorm on q and k
    attn_logit_softcap: float = 0.0   # gemma2: tanh softcap on attention logits
    final_logit_softcap: float = 0.0  # gemma2: tanh softcap on LM logits
    sliding_window: int = 0           # 0 = full attention (mixtral/gemma2-local: 4096)
    local_global: bool = False        # gemma2: alternate sliding/global layers
    global_window_long: int = 0       # long-context mode: window used for 'global'
    #                                   layers (documented gemma2 deviation, DESIGN §4)
    rope_theta: float = 10000.0
    use_post_norm: bool = False       # gemma2 sandwich norms
    mlp_act: str = "silu"             # silu (swiglu) | gelu (geglu) | gelu_mlp (2-mat)
    embed_scale: bool = False         # gemma: scale embeddings by sqrt(d_model)

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 2.0

    # --- SSM (mamba2, used by hybrid) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256              # SSD chunk length for prefill/train

    # --- RWKV6 ---
    rwkv: bool = False
    rwkv_head_dim: int = 64
    rwkv_lora_dim: int = 64           # decay/token-shift LoRA rank

    # --- hybrid (zamba2) ---
    shared_attn_every: int = 0        # apply the shared attention block every k
    #                                   mamba layers (weights shared, caches not)

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0

    # --- modality frontend stubs (DESIGN §4: the one allowed stub) ---
    frontend: str = ""                # "" | "vision" | "audio"
    num_frontend_tokens: int = 0      # image patch tokens prepended to prompt

    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    source: str = ""                  # citation for the config numbers

    # ------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == SSM

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    # --- mamba2 derived dims ---
    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    # ------------------------------------------------------------------
    def layer_window(self, layer_idx: int, long_context: bool = False) -> int:
        """Effective attention window of layer ``layer_idx`` (0 = unbounded).

        gemma2 alternates sliding/global; in long-context mode the global
        layers are also windowed (DESIGN.md §8.4).
        """
        if self.local_global:
            if layer_idx % 2 == 0:
                return self.sliding_window
            return self.global_window_long if long_context else 0
        return self.sliding_window

    def supports_long_context(self) -> bool:
        """Whether long_500k decode is sub-quadratic / bounded-state for this arch."""
        if self.family in (SSM, HYBRID):
            return True
        if self.sliding_window > 0 and (not self.local_global or self.global_window_long > 0):
            return True
        if self.local_global and self.global_window_long > 0:
            return True
        return False

    def num_params(self) -> int:
        """Approximate parameter count (used by the perf model and rooflines)."""
        d, hd = self.d_model, self.head_dim_
        p = 0
        p += self.vocab_size * d  # embed
        if not self.tie_embeddings:
            p += self.vocab_size * d  # lm head
        if self.family == SSM:  # rwkv6
            per = (
                4 * d * d  # r,k,v,out (time mix)
                + d * self.rwkv_heads * self.rwkv_head_dim  # gate approx
                + 2 * self.rwkv_lora_dim * d * 2  # decay/x loras
                + 2 * d * self.d_ff  # channel mix
            )
            return p + per * self.num_layers
        attn = d * (self.num_heads * hd) + d * (2 * self.num_kv_heads * hd) + (self.num_heads * hd) * d
        if self.mlp_act == "gelu_mlp":
            mlp = 2 * d * self.d_ff
        else:
            mlp = 3 * d * self.d_ff
        if self.is_moe:
            mlp = self.num_experts * mlp + d * self.num_experts
        if self.family == HYBRID:
            m = self._mamba_params()
            n_shared = self.num_layers // max(self.shared_attn_every, 1)
            return p + m * self.num_layers + (attn + 3 * d * self.d_ff)  # one shared block
        per = attn + mlp
        if self.is_encoder_decoder:
            # encoder layers: attn + gelu mlp; decoder adds cross-attn
            enc = attn + mlp
            dec = 2 * attn + mlp
            return p + enc * self.encoder_layers + dec * self.num_layers
        return p + per * self.num_layers

    def num_active_params(self) -> int:
        """Active params per token (MoE: only routed experts). For 6*N*D FLOPs."""
        if not self.is_moe:
            return self.num_params()
        d = self.d_model
        mlp_all = self.num_experts * 3 * d * self.d_ff
        mlp_act = self.experts_per_token * 3 * d * self.d_ff
        return self.num_params() - (mlp_all - mlp_act) * self.num_layers

    def _mamba_params(self) -> int:
        d, di, ns = self.d_model, self.ssm_d_inner, self.ssm_state
        nh = self.ssm_nheads
        in_proj = d * (2 * di + 2 * ns + nh)  # z, x, B, C, dt
        conv = (di + 2 * ns) * self.ssm_conv
        out = di * d
        return in_proj + conv + out + 2 * nh

    def reduced(self, *, layers: int = 2, d_model: int = 256, max_experts: int = 4,
                vocab: int = 512, d_ff: int = 0) -> "ModelConfig":
        """Smoke-test variant: same family/feature set, tiny dims (assignment spec)."""
        ratio = max(1, self.d_model // d_model)
        nh = max(2, self.num_heads // ratio)
        nkv = max(1, min(self.num_kv_heads, nh))
        while nh % nkv:
            nkv -= 1
        hd = d_model // nh
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=layers,
            d_model=d_model,
            num_heads=nh,
            num_kv_heads=nkv,
            head_dim=hd,
            d_ff=d_ff or max(64, self.d_ff // ratio),
            vocab_size=min(self.vocab_size, vocab),
            num_experts=min(self.num_experts, max_experts) if self.is_moe else 0,
            experts_per_token=min(self.experts_per_token, 2) if self.is_moe else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            global_window_long=min(self.global_window_long, 128) if self.global_window_long else 0,
            ssm_head_dim=32 if self.ssm_state else self.ssm_head_dim,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_chunk=16,
            rwkv_head_dim=32 if self.rwkv else self.rwkv_head_dim,
            rwkv_lora_dim=16 if self.rwkv else self.rwkv_lora_dim,
            shared_attn_every=2 if self.shared_attn_every else 0,
            encoder_layers=min(self.encoder_layers, 2),
            num_frontend_tokens=min(self.num_frontend_tokens, 16),
        )


@dataclass(frozen=True)
class InputShape:
    """One of the four assigned (seq_len, global_batch) workload shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
