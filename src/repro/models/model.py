"""Model factory: config -> model object with the uniform API."""
from __future__ import annotations

from repro.models.config import AUDIO, DENSE, HYBRID, MOE, SSM, VLM, ModelConfig
from repro.models.rwkv_model import RWKVModel
from repro.models.transformer import Transformer
from repro.models.whisper import WhisperModel
from repro.models.zamba2 import Zamba2Model


def build_model(cfg: ModelConfig, **kw):
    if cfg.family in (DENSE, MOE, VLM):
        return Transformer(cfg, **kw)
    if cfg.family == SSM:
        return RWKVModel(cfg, **kw)
    if cfg.family == HYBRID:
        return Zamba2Model(cfg, **kw)
    if cfg.family == AUDIO:
        return WhisperModel(cfg, **kw)
    raise ValueError(f"unknown family {cfg.family}")
