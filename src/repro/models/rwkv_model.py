"""RWKV6 full model: embed -> [time_mix + channel_mix] x L -> head.

Decode state is constant-size (token-shift vectors + per-head WKV matrices),
so prefill and decode share one forward path (decode = prefill with S=1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers, rwkv6
from repro.sharding.ctx import constrain
from repro.models.config import ModelConfig


def init_rwkv_block(rng, cfg: ModelConfig):
    k1, k2 = jax.random.split(rng)
    return {
        "ln1": layers.init_rmsnorm(cfg.d_model, cfg.jnp_dtype),
        "ln2": layers.init_rmsnorm(cfg.d_model, cfg.jnp_dtype),
        "body": rwkv6.init_rwkv(k1, cfg),
    }


class RWKVModel:
    def __init__(self, cfg: ModelConfig, *, remat: bool = True, **_):
        self.cfg = cfg
        self.remat = remat

    def init(self, rng):
        cfg = self.cfg
        ke, kl, kh = jax.random.split(rng, 3)
        lp = jax.vmap(lambda r: init_rwkv_block(r, cfg))(jax.random.split(kl, cfg.num_layers))
        return {
            "embed": (jax.random.normal(ke, (cfg.vocab_size, cfg.d_model),
                                        jnp.float32) * 0.02).astype(cfg.jnp_dtype),
            "layers": lp,
            "final_norm": layers.init_rmsnorm(cfg.d_model, cfg.jnp_dtype),
            "lm_head": (jax.random.normal(kh, (cfg.d_model, cfg.vocab_size),
                                          jnp.float32) * 0.02).astype(cfg.jnp_dtype),
        }

    def init_cache(self, batch_size: int, cache_len: int = 0, prefilled_len: int = 0):
        """cache_len is irrelevant for a recurrent model (state is O(1) in seq)."""
        cfg = self.cfg
        st = rwkv6.init_rwkv_state(cfg, batch_size)
        st = jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.num_layers, *a.shape)), st)
        st = dict(st, pos=jnp.full((batch_size,), prefilled_len, jnp.int32))
        return st

    def _forward(self, params, x, state):
        cfg = self.cfg

        def body(x, lp_state):
            x = constrain(x, "act_btd")
            lp, tm_x, cm_x, wkv = lp_state
            h = layers.rmsnorm(lp["ln1"], x, cfg.norm_eps)
            out, tm_x, wkv = rwkv6.time_mix(lp["body"]["tm"], h, cfg, tm_x, wkv)
            x = x + out
            h = layers.rmsnorm(lp["ln2"], x, cfg.norm_eps)
            out, cm_x = rwkv6.channel_mix(lp["body"]["cm"], h, cfg, cm_x)
            return x + out, (tm_x, cm_x, wkv)

        if self.remat:
            body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        xs = (params["layers"], state["tm_x"], state["cm_x"], state["wkv"])
        x, (tm_x, cm_x, wkv) = jax.lax.scan(body, x, xs)
        return x, {"tm_x": tm_x, "cm_x": cm_x, "wkv": wkv}

    def prefill(self, params, batch, cache_len: int = 0):
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = constrain(params["embed"][tokens], "act_btd")
        state = self.init_cache(B)
        x, new_state = self._forward(params, x, state)
        lens = batch.get("lengths")
        if lens is None:
            lens = jnp.full((B,), S, jnp.int32)
        last = jnp.take_along_axis(x, (lens - 1)[:, None, None], axis=1)[:, 0]
        logits = self._logits(params, last)
        new_state["pos"] = lens.astype(jnp.int32)
        return logits, new_state

    def decode_step(self, params, tokens, cache):
        x = params["embed"][tokens[:, None]]
        x, new_state = self._forward(params, x, cache)
        new_state["pos"] = cache["pos"] + 1
        return self._logits(params, x[:, 0]), new_state

    def _logits(self, params, x):
        x = layers.rmsnorm(params["final_norm"], x, self.cfg.norm_eps)
        return (x @ params["lm_head"]).astype(jnp.float32)

    def loss(self, params, batch):
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = constrain(params["embed"][tokens], "act_btd")
        x, _ = self._forward(params, x, self.init_cache(B))
        x = layers.rmsnorm(params["final_norm"], x, self.cfg.norm_eps)
        logits = (x @ params["lm_head"]).astype(jnp.float32)
        return layers.cross_entropy_loss(logits, batch["labels"])
