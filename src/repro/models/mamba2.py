"""Mamba2 (SSD) block — chunked prefill/train + O(1) decode step.

Follows the scalar-A-per-head SSD formulation (Dao & Gu, 2024): within a
chunk the output is computed with an attention-like quadratic einsum over
the chunk, and chunk-boundary states are carried by a short lax.scan. This
keeps train-time scan carries to S/chunk states instead of S (critical for
the train_4k shape) and maps onto the MXU as batched GEMMs.

Decode carries (conv_state, ssm_state) — constant in sequence length, which
is exactly why zamba2/rwkv-class models run the long_500k shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models.config import ModelConfig


def init_mamba(rng, cfg: ModelConfig):
    d, di, ns = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state
    nh = cfg.ssm_nheads
    dt_ = cfg.jnp_dtype
    ks = jax.random.split(rng, 3)
    d_in_proj = 2 * di + 2 * ns + nh  # z, x, B, C, dt
    conv_dim = di + 2 * ns
    a_init = jnp.log(jnp.linspace(1.0, 16.0, nh))
    dt_bias = jnp.log(jnp.exp(jnp.linspace(1e-3, 1e-1, nh)) - 1.0)  # softplus^-1
    return {
        "in_proj": layers.init_dense(ks[0], d, d_in_proj, dt_),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim), jnp.float32) * 0.02).astype(dt_),
        "conv_b": jnp.zeros((conv_dim,), dt_),
        "A_log": a_init.astype(jnp.float32),     # A = -exp(A_log), per head
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "out_proj": layers.init_dense(ks[2], di, d, dt_, scale=0.02 / np.sqrt(2 * cfg.num_layers)),
        "norm": layers.init_rmsnorm(di, dt_),
    }


def _split_proj(cfg: ModelConfig, zxbcdt):
    di, ns, nh = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_nheads
    z, xbc, dt = jnp.split(zxbcdt, [di, di + di + 2 * ns], axis=-1)
    return z, xbc, dt  # xbc holds x|B|C for the conv


def _split_xbc(cfg: ModelConfig, xbc):
    di, ns = cfg.ssm_d_inner, cfg.ssm_state
    x, B, C = jnp.split(xbc, [di, di + ns], axis=-1)
    return x, B, C


def mamba_prefill(p, u, cfg: ModelConfig, conv_state=None, ssm_state=None,
                  mask=None):
    """u: (B, S, d) -> (y (B,S,d), (conv_state, ssm_state)).

    S is padded internally to a multiple of cfg.ssm_chunk. ``mask`` (B,S)
    marks valid tokens: invalid tokens get dt=0 which makes them state-
    transparent (decay exp(0)=1, contribution dt·B·x=0), so trailing padding
    never corrupts the carried recurrent state.
    """
    Bsz, S_in, _ = u.shape
    Q = min(cfg.ssm_chunk, max(S_in, 1))
    pad_len = (-S_in) % Q
    if pad_len:
        u = jnp.pad(u, ((0, 0), (0, pad_len), (0, 0)))
        if mask is None:
            mask = jnp.arange(S_in + pad_len)[None, :] < S_in
        else:
            mask = jnp.pad(mask, ((0, 0), (0, pad_len)))
    Bsz, S, _ = u.shape
    nh, hd, ns = cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state
    di = cfg.ssm_d_inner

    zxbcdt = layers.dense(p["in_proj"], u)
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)

    # causal depthwise conv over the sequence, seeded from conv_state
    K = cfg.ssm_conv
    if conv_state is None:
        conv_state = jnp.zeros((Bsz, K - 1, xbc.shape[-1]), xbc.dtype)
    xbc_pad = jnp.concatenate([conv_state, xbc], axis=1)
    # conv state = taps preceding the first *unseen* position (ignores padding)
    new_conv_state = xbc_pad[:, S_in:S_in + K - 1, :]
    xbc_conv = sum(xbc_pad[:, i:i + S, :] * p["conv_w"][i] for i in range(K))
    xbc_conv = jax.nn.silu(xbc_conv + p["conv_b"])
    x, Bm, Cm = _split_xbc(cfg, xbc_conv)

    x = x.reshape(Bsz, S, nh, hd)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])      # (B,S,nh)
    if mask is not None:
        dt = dt * mask[:, :, None].astype(jnp.float32)  # padding: state-transparent
    A = -jnp.exp(p["A_log"])                                             # (nh,)
    dA = dt * A                                                          # (B,S,nh) log-decay
    Bm = Bm.astype(jnp.float32)  # (B,S,ns) — ngroups=1, shared across heads
    Cm = Cm.astype(jnp.float32)

    nchunk = S // Q
    xc = x.reshape(Bsz, nchunk, Q, nh, hd).astype(jnp.float32)
    dtc = dt.reshape(Bsz, nchunk, Q, nh)
    dAc = dA.reshape(Bsz, nchunk, Q, nh)
    Bc = Bm.reshape(Bsz, nchunk, Q, ns)
    Cc = Cm.reshape(Bsz, nchunk, Q, ns)

    if ssm_state is None:
        ssm_state = jnp.zeros((Bsz, nh, hd, ns), jnp.float32)
    tri = jnp.tril(jnp.ones((Q, Q), jnp.float32))

    def chunk_step(state, inp):
        """One SSD chunk: quadratic intra-chunk + state in/out. Processing
        chunks sequentially keeps the (Q, Q, nh) score tensor per-chunk only
        (materializing all chunks at once is O(S·Q·nh) — catastrophic for
        train_4k; see EXPERIMENTS.md §Perf)."""
        xq, dtq, dAq, Bq, Cq = inp          # (B,Q,...) one chunk
        cum = jnp.cumsum(dAq, axis=1)       # (B,Q,nh)
        total = cum[:, -1, :]               # (B,nh)
        # intra: score[i,j] = C_i·B_j exp(cum_i - cum_j) dt_j, j <= i
        cb = jnp.einsum("bis,bjs->bij", Cq, Bq)                       # (B,Q,Q)
        decay = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])      # (B,Q,Q,nh)
        scores = cb[..., None] * decay * dtq[:, None, :, :] * tri[None, :, :, None]
        y_intra = jnp.einsum("bijh,bjhp->bihp", scores, xq)           # (B,Q,nh,hd)
        # inter: contribution of the state entering this chunk
        y_inter = jnp.einsum("bis,bih,bhps->bihp", Cq, jnp.exp(cum), state)
        # state update
        sdecay = jnp.exp(total[:, None, :] - cum) * dtq               # (B,Q,nh)
        chunk_state = jnp.einsum("bjh,bjs,bjhp->bhps", sdecay, Bq, xq)
        new_state = jnp.exp(total)[:, :, None, None] * state + chunk_state
        return new_state, y_intra + y_inter

    xs = tuple(a.transpose(1, 0, *range(2, a.ndim))
               for a in (xc, dtc, dAc, Bc, Cc))
    final_state, ys = jax.lax.scan(chunk_step, ssm_state, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, S, nh, hd)
    y = y + p["D"][None, None, :, None] * x.astype(jnp.float32)
    y = y.reshape(Bsz, S, di).astype(u.dtype)
    y = layers.rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    y = layers.dense(p["out_proj"], y)
    if pad_len:
        y = y[:, :S_in]
    return y, (new_conv_state, final_state)


def mamba_decode(p, u, cfg: ModelConfig, conv_state, ssm_state):
    """u: (B, 1, d) single token. States: conv (B,K-1,conv_dim), ssm (B,nh,hd,ns)."""
    Bsz = u.shape[0]
    nh, hd, ns, di = cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_d_inner
    K = cfg.ssm_conv

    zxbcdt = layers.dense(p["in_proj"], u[:, 0])                         # (B, proj)
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)

    window = jnp.concatenate([conv_state, xbc[:, None, :]], axis=1)      # (B,K,conv)
    new_conv_state = window[:, 1:, :]
    xbc_conv = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"])
    x, Bm, Cm = _split_xbc(cfg, xbc_conv)

    x = x.reshape(Bsz, nh, hd).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])      # (B,nh)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A)                                              # (B,nh)
    Bm = Bm.astype(jnp.float32)
    Cm = Cm.astype(jnp.float32)

    new_state = (decay[:, :, None, None] * ssm_state
                 + jnp.einsum("bh,bs,bhp->bhps", dt, Bm, x))
    y = jnp.einsum("bs,bhps->bhp", Cm, new_state) + p["D"][None, :, None] * x
    y = y.reshape(Bsz, di).astype(u.dtype)
    y = layers.rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return layers.dense(p["out_proj"], y)[:, None, :], (new_conv_state, new_state)


def mamba_ref_scan(p, u, cfg: ModelConfig):
    """Token-by-token oracle (decode step iterated) for testing the chunked path."""
    Bsz, S, _ = u.shape
    conv = jnp.zeros((Bsz, cfg.ssm_conv - 1, cfg.ssm_d_inner + 2 * cfg.ssm_state), u.dtype)
    ssm = jnp.zeros((Bsz, cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
    ys = []
    for t in range(S):
        y, (conv, ssm) = mamba_decode(p, u[:, t:t + 1], cfg, conv, ssm)
        ys.append(y)
    return jnp.concatenate(ys, axis=1), (conv, ssm)
