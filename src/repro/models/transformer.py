"""Generic decoder-only transformer: dense, MoE and VLM families.

Functional model object with a uniform API consumed by the serving engine,
the training loop and the multi-pod dry-run:

  init(rng) -> params
  prefill(params, batch) -> (last_token_logits (B,V), cache)
  decode_step(params, tokens (B,), cache) -> (logits (B,V), cache)
  init_cache(batch, cache_len, prefilled_len) -> cache (zeros, for dry-run)
  loss(params, batch) -> scalar

Layers are stacked and executed with lax.scan (pairs of layers for gemma2's
local/global alternation) to keep HLO size O(1) in depth.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention, layers, moe as moe_lib
from repro.sharding.ctx import constrain
from repro.models.config import AUDIO, VLM, ModelConfig

Params = Any


def _norm_init(cfg: ModelConfig, d: int):
    if cfg.family == AUDIO:
        return layers.init_layernorm(d, cfg.jnp_dtype)
    return layers.init_rmsnorm(d, cfg.jnp_dtype)


def _norm(cfg: ModelConfig, p, x):
    if cfg.family == AUDIO:
        return layers.layernorm(p, x, cfg.norm_eps)
    return layers.rmsnorm(p, x, cfg.norm_eps)


def init_block(rng, cfg: ModelConfig):
    """One transformer block (attention + MLP/MoE + norms)."""
    ks = jax.random.split(rng, 4)
    p = {
        "ln1": _norm_init(cfg, cfg.d_model),
        "attn": attention.init_attn(ks[0], cfg),
        "ln2": _norm_init(cfg, cfg.d_model),
    }
    if cfg.is_moe:
        p["moe"] = moe_lib.init_moe(ks[1], cfg)
    else:
        p["mlp"] = layers.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.jnp_dtype, cfg.mlp_act)
    if cfg.use_post_norm:
        p["post_ln1"] = _norm_init(cfg, cfg.d_model)
        p["post_ln2"] = _norm_init(cfg, cfg.d_model)
    return p


def block_prefill(p, x, positions, cfg: ModelConfig, *, window: int,
                  kv_lens=None, cache_len: int = 0, impl: str = "xla",
                  moe_groups: int = 16, cache_dtype=None):
    """Returns (x, (cache_k, cache_v), aux_loss). cache_len>0 builds a decode cache."""
    h = _norm(cfg, p["ln1"], x)
    a, (k, v) = attention.attn_prefill(p["attn"], h, positions, cfg,
                                       window=window, kv_lens=kv_lens, impl=impl)
    if cfg.use_post_norm:
        a = _norm(cfg, p["post_ln1"], a)
    x = x + a
    h = _norm(cfg, p["ln2"], x)
    aux = jnp.zeros((), jnp.float32)
    if cfg.is_moe:
        m, aux = moe_lib.moe_mlp(p["moe"], h, cfg, groups=moe_groups)
    else:
        m = layers.mlp(p["mlp"], h, cfg.mlp_act)
    if cfg.use_post_norm:
        m = _norm(cfg, p["post_ln2"], m)
    x = x + m
    kv_cache = None
    if cache_len:
        kv_cache = attention.write_prefill_cache(k, v, cache_len,
                                                 dtype=cache_dtype)
    return x, kv_cache, aux


def block_decode(p, x, positions, cfg: ModelConfig, cache_k, cache_v, lengths,
                 *, impl: str = "xla", moe_groups: int = 16):
    """x (B,1,d). Writes the current token's KV then attends. Returns (x, ck, cv)."""
    h = _norm(cfg, p["ln1"], x)
    k_new, v_new = attention.project_kv_for_cache(p["attn"], h, positions, cfg)
    cache_k, cache_v = attention.write_decode_cache(cache_k, cache_v, k_new, v_new, positions)
    a = attention.attn_decode(p["attn"], h, cache_k, cache_v, positions, lengths, cfg, impl=impl)
    if cfg.use_post_norm:
        a = _norm(cfg, p["post_ln1"], a)
    x = x + a
    h = _norm(cfg, p["ln2"], x)
    if cfg.is_moe:
        m, _ = moe_lib.moe_mlp(p["moe"], h, cfg, groups=moe_groups)
    else:
        m = layers.mlp(p["mlp"], h, cfg.mlp_act)
    if cfg.use_post_norm:
        m = _norm(cfg, p["post_ln2"], m)
    return x + m, cache_k, cache_v


class Transformer:
    """Dense / MoE / VLM decoder-only model."""

    def __init__(self, cfg: ModelConfig, *, impl: str = "xla", moe_groups: int = 16,
                 long_context: bool = False, remat: bool = True,
                 cache_dtype: str | None = None):
        self.cfg = cfg
        self.impl = impl
        self.moe_groups = moe_groups
        self.long_context = long_context
        self.remat = remat
        # quantized KV cache (e.g. "float8_e4m3fn"): halves decode cache HBM
        # footprint and bandwidth; attention math upcasts to f32 on read
        self.cache_dtype = jnp.dtype(cache_dtype) if cache_dtype else cfg.jnp_dtype
        if cfg.local_global:
            assert cfg.num_layers % 2 == 0, "local/global alternation needs even layers"

    # --- parameters -------------------------------------------------------
    def init(self, rng) -> Params:
        cfg = self.cfg
        k_embed, k_layers, k_head = jax.random.split(rng, 3)
        lkeys = jax.random.split(k_layers, cfg.num_layers)
        lp = jax.vmap(lambda r: init_block(r, cfg))(lkeys)
        if cfg.local_global:  # restack (L,) -> (L/2, 2)
            lp = jax.tree.map(lambda a: a.reshape(cfg.num_layers // 2, 2, *a.shape[1:]), lp)
        p = {
            "embed": (jax.random.normal(k_embed, (cfg.vocab_size, cfg.d_model),
                                        jnp.float32) * 0.02).astype(cfg.jnp_dtype),
            "layers": lp,
            "final_norm": _norm_init(cfg, cfg.d_model),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = (jax.random.normal(k_head, (cfg.d_model, cfg.vocab_size),
                                              jnp.float32) * 0.02).astype(cfg.jnp_dtype)
        return p

    # --- helpers ----------------------------------------------------------
    def _embed(self, params, tokens):
        x = params["embed"][tokens]
        if self.cfg.embed_scale:
            x = x * jnp.asarray(np.sqrt(self.cfg.d_model), x.dtype)
        return constrain(x, "act_btd")

    def _logits(self, params, x):
        head = params["embed"].T if self.cfg.tie_embeddings else params["lm_head"]
        logits = _norm(self.cfg, params["final_norm"], x) @ head
        return layers.softcap(logits.astype(jnp.float32), self.cfg.final_logit_softcap)

    def _windows(self) -> list[int]:
        cfg = self.cfg
        if cfg.local_global:
            return [cfg.layer_window(0, self.long_context),
                    cfg.layer_window(1, self.long_context)]
        return [cfg.layer_window(0, self.long_context)]

    def _cache_sizes(self, seq_len: int) -> list[int]:
        return [min(w, seq_len) if w else seq_len for w in self._windows()]

    def _maybe_remat(self, f):
        if not self.remat:
            return f
        return jax.checkpoint(f, policy=jax.checkpoint_policies.nothing_saveable)

    def _train_impl(self, seq_len: int) -> str:
        # naive attention trains with less backward memory at moderate seqs
        if self.impl == "xla" and seq_len <= 8192:
            return "xla_naive"
        return self.impl

    # --- forward over the full sequence ------------------------------------
    def _forward(self, params, x, positions, kv_lens, cache_len: int,
                 impl: str | None = None):
        """Runs all layers; returns (hidden, caches, total_aux)."""
        cfg = self.cfg
        impl = impl or self.impl
        windows = self._windows()
        cache_sizes = self._cache_sizes(cache_len) if cache_len else [0] * len(windows)

        def body(carry, lp):
            x, aux = carry
            x = constrain(x, "act_btd")
            outs = []
            for i, (w, cs) in enumerate(zip(windows, cache_sizes)):
                sub = jax.tree.map(lambda a: a[i], lp) if cfg.local_global else lp
                x, kv, a = block_prefill(
                    sub, x, positions, cfg, window=w, kv_lens=kv_lens,
                    cache_len=cs, impl=impl, moe_groups=self.moe_groups,
                    cache_dtype=self.cache_dtype)
                aux = aux + a
                outs.append(kv)
            return (x, aux), outs

        (x, aux), caches = jax.lax.scan(
            self._maybe_remat(body), (x, jnp.zeros((), jnp.float32)), params["layers"])
        return x, caches, aux

    # --- public API ---------------------------------------------------------
    def prefill(self, params, batch, cache_len: int = 0):
        """batch: tokens (B,S) [+ frontend_embeds (B,T,d)] [+ lengths (B,)].
        Returns (last-token logits (B,V), cache|None)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = self._embed(params, tokens)
        if cfg.family == VLM and "frontend_embeds" in batch:
            fe = batch["frontend_embeds"].astype(x.dtype)
            x = jnp.concatenate([fe, x], axis=1)  # image tokens first
            S = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        kv_lens = batch.get("lengths")
        if kv_lens is None:
            kv_lens = jnp.full((B,), S, jnp.int32)
        x, caches, _ = self._forward(params, x, positions, kv_lens, cache_len or S)
        last = jnp.take_along_axis(x, (kv_lens - 1)[:, None, None], axis=1)[:, 0]
        logits = self._logits(params, last)
        cache = None
        if cache_len:
            cache = self._pack_cache(caches, kv_lens)
        return logits, cache

    def _pack_cache(self, caches, lengths):
        cache = {"pos": lengths.astype(jnp.int32)}
        for i, kv in enumerate(caches):
            k, v = kv
            cache[f"k{i}"], cache[f"v{i}"] = k, v
        return cache

    def init_cache(self, batch_size: int, cache_len: int, prefilled_len: int = 0):
        """Zero cache for dry-run decode lowering (no prefill executed)."""
        cfg = self.cfg
        hd = cfg.head_dim_
        cache = {"pos": jnp.full((batch_size,), prefilled_len, jnp.int32)}
        L = cfg.num_layers // (2 if cfg.local_global else 1)
        for i, cs in enumerate(self._cache_sizes(cache_len)):
            shape = (L, batch_size, cs, cfg.num_kv_heads, hd)
            cache[f"k{i}"] = jnp.zeros(shape, self.cache_dtype)
            cache[f"v{i}"] = jnp.zeros(shape, self.cache_dtype)
        return cache

    def decode_step(self, params, tokens, cache):
        """tokens (B,) int32. Returns (logits (B,V), new cache)."""
        cfg = self.cfg
        B = tokens.shape[0]
        positions = cache["pos"]
        lengths = positions + 1
        x = self._embed(params, tokens[:, None])
        n_classes = 2 if cfg.local_global else 1

        def body(x, lp_and_cache):
            lp = lp_and_cache[0]
            kvs = lp_and_cache[1:]
            new_kvs = []
            for i in range(n_classes):
                sub = jax.tree.map(lambda a: a[i], lp) if cfg.local_global else lp
                ck, cv = kvs[2 * i], kvs[2 * i + 1]
                x, ck, cv = block_decode(sub, x, positions, cfg, ck, cv, lengths,
                                         impl=self.impl, moe_groups=self.moe_groups)
                new_kvs += [ck, cv]
            return x, tuple(new_kvs)

        xs = [params["layers"]]
        for i in range(n_classes):
            xs += [cache[f"k{i}"], cache[f"v{i}"]]
        x, new_caches = jax.lax.scan(body, x, tuple(xs))
        logits = self._logits(params, x[:, 0])
        new_cache = {"pos": positions + 1}
        for i in range(n_classes):
            new_cache[f"k{i}"], new_cache[f"v{i}"] = new_caches[2 * i], new_caches[2 * i + 1]
        return logits, new_cache

    def loss(self, params, batch):
        """batch: tokens (B,S), labels (B,S) with -1 ignored."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = self._embed(params, tokens)
        if cfg.family == VLM and "frontend_embeds" in batch:
            fe = batch["frontend_embeds"].astype(x.dtype)
            T = fe.shape[1]
            x = jnp.concatenate([fe, x], axis=1)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None], (B, x.shape[1]))
        kv_lens = jnp.full((B,), x.shape[1], jnp.int32)
        x, _, aux = self._forward(params, x, positions, kv_lens, 0,
                                  impl=self._train_impl(x.shape[1]))
        if cfg.family == VLM and "frontend_embeds" in batch:
            x = x[:, T:]
        logits = self._logits(params, x)
        ce = layers.cross_entropy_loss(logits, batch["labels"])
        return ce + 0.01 * aux
