"""RWKV6 "Finch" block — attention-free, data-dependent per-channel decay.

Time-mix: linear-attention-like recurrence with a (head_dim x head_dim)
per-head state, decay w_t computed per token/channel through a LoRA
(the defining RWKV6 feature, arXiv:2404.05892). Channel-mix: squared-ReLU
FFN with token shift. Decode state is O(d·head_dim) — constant in sequence
length, hence this arch runs the long_500k shape.

Prefill uses a time scan (linear); a chunked formulation mirroring the
mamba2 SSD path is a recorded perf-iteration candidate (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models.config import ModelConfig


def init_rwkv(rng, cfg: ModelConfig):
    d, H, hd, r = cfg.d_model, cfg.rwkv_heads, cfg.rwkv_head_dim, cfg.rwkv_lora_dim
    dt = cfg.jnp_dtype
    ks = jax.random.split(rng, 10)
    def w(key, shape, scale=0.02):
        return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dt)
    return {
        "tm": {  # time mix
            "mix": w(ks[0], (5, d), 0.2),  # static shift-mix for r,k,v,w,g
            "wr": w(ks[1], (d, H * hd)),
            "wk": w(ks[2], (d, H * hd)),
            "wv": w(ks[3], (d, H * hd)),
            "wg": w(ks[4], (d, H * hd)),
            "wo": w(ks[5], (H * hd, d), 0.02 / np.sqrt(2 * cfg.num_layers)),
            "w_base": jnp.full((H * hd,), -6.0, jnp.float32),  # decay bias
            "w_lora_a": w(ks[6], (d, r)),
            "w_lora_b": w(ks[7], (r, H * hd), 0.1),
            "u": jnp.zeros((H, hd), jnp.float32),  # current-token bonus
            "ln": layers.init_rmsnorm(hd, dt),     # per-head output norm
        },
        "cm": {  # channel mix
            "mix": w(ks[8], (2, d), 0.2),
            "wk": w(ks[9], (d, cfg.d_ff)),
            "wv": w(jax.random.fold_in(ks[9], 1), (cfg.d_ff, d),
                    0.02 / np.sqrt(2 * cfg.num_layers)),
            "wr": w(jax.random.fold_in(ks[9], 2), (d, d)),
        },
    }


def _shift(x, last):
    """Token shift: prev token per position. x (B,S,d), last (B,d)."""
    return jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)


def _tm_inputs(p, x, last):
    xs = _shift(x, last)
    mix = jax.nn.sigmoid(p["mix"].astype(jnp.float32))  # (5, d)
    def mx(i):
        m = mix[i].astype(x.dtype)
        return x * m + xs * (1 - m)
    r = layers.dense({"w": p["wr"]}, mx(0))
    k = layers.dense({"w": p["wk"]}, mx(1))
    v = layers.dense({"w": p["wv"]}, mx(2))
    xw = mx(3)
    g = jax.nn.silu(layers.dense({"w": p["wg"]}, mx(4)))
    lora = jnp.tanh(xw.astype(jnp.float32) @ p["w_lora_a"].astype(jnp.float32))
    w_log = p["w_base"] + lora @ p["w_lora_b"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w_log))  # (B,S,H*hd) in (0,1), data-dependent
    return r, k, v, w, g


def _wkv_scan(r, k, v, w, u, state):
    """r,k,v,w: (B,S,H,hd) f32; state (B,H,hd,hd). Returns y (B,S,H,hd), state."""
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp  # (B,H,hd)
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., None] * s + kv
        return s, y
    xs = tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, w))
    state, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3), state


def _wkv_chunked(r, k, v, w, u, state, chunk: int = 32):
    """Chunked WKV6: intra-chunk pairwise per-channel decays + short scan
    over chunk boundaries (mirrors the mamba2 SSD structure).

    Per-token scans save S carries for backward (8+ GB at train_4k); the
    chunked form saves S/chunk states and computes intra-chunk terms as
    (Q,Q,K) einsums on the MXU. Numerics: all pairwise exponents
    lw[t-1]-lw[j] (j<=t-1) and lw[Q]-lw[j] are <= 0 because lw=cumsum(log w)
    decreases, so every exp() is bounded by 1 (EXPERIMENTS.md §Perf).
    """
    B, S, H, K = r.shape
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:  # state-transparent padding: k=0, w=1 contribute nothing
        zeros = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zeros(r), zeros(k), zeros(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    n = (S + pad) // Q

    def reshape(a):
        return a.reshape(B, n, Q, H, K).transpose(1, 0, 2, 3, 4)  # (n,B,Q,H,K)

    rc, kc, vc, wc = map(reshape, (r, k, v, w))
    tri_strict = jnp.tril(jnp.ones((Q, Q), jnp.float32), k=-1)

    def chunk_step(s, inp):
        rq, kq, vq, wq = inp                       # (B,Q,H,K)
        lw = jnp.cumsum(jnp.log(jnp.maximum(wq, 1e-30)), axis=1)  # (B,Q,H,K)
        lw_prev = lw - jnp.log(jnp.maximum(wq, 1e-30))            # lw[t-1]
        # intra: scores[t,j] = sum_k r_t k_j exp(lw[t-1]-lw[j]), j <= t-1
        diff = lw_prev[:, :, None] - lw[:, None, :, :]            # (B,Q,Q,H,K)
        scores = jnp.einsum("bthk,bjhk,btjhk->bhtj", rq, kq,
                            jnp.exp(jnp.minimum(diff, 0.0)))
        scores = scores * tri_strict[None, None]
        y_intra = jnp.einsum("bhtj,bjhv->bthv", scores, vq)
        # diagonal bonus: (r_t . (u*k_t)) v_t
        diag = jnp.einsum("bthk,hk,bthk->bth", rq, u, kq)
        y_intra = y_intra + diag[..., None] * vq
        # inter: r_t * exp(lw[t-1]) against the incoming state
        rdec = rq * jnp.exp(lw_prev)
        y_inter = jnp.einsum("bthk,bhkv->bthv", rdec, s)
        # state update: S' = diag(exp(lw_Q)) S + sum_j diag(exp(lw_Q-lw_j)) k_j^T v_j
        lw_last = lw[:, -1][:, None]                              # (B,1,H,K)
        kdec = kq * jnp.exp(jnp.minimum(lw_last - lw, 0.0))
        s = (jnp.exp(lw[:, -1])[..., None] * s
             + jnp.einsum("bjhk,bjhv->bhkv", kdec, vq))
        return s, y_intra + y_inter

    state, ys = jax.lax.scan(chunk_step, state, (rc, kc, vc, wc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, n * Q, H, K)
    return y[:, :S], state


def time_mix(p, x, cfg: ModelConfig, last_x, wkv_state, *,
             wkv_impl: str = "chunked"):
    B, S, d = x.shape
    H, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    r, k, v, w, g = _tm_inputs(p, x, last_x)
    shp = (B, S, H, hd)
    r, k, v = (a.reshape(shp).astype(jnp.float32) for a in (r, k, v))
    w = w.reshape(shp)
    if wkv_impl == "chunked" and S > 1:
        y, wkv_state = _wkv_chunked(r, k, v, w, p["u"], wkv_state)
    else:
        y, wkv_state = _wkv_scan(r, k, v, w, p["u"], wkv_state)
    y = layers.rmsnorm(p["ln"], y.astype(x.dtype), cfg.norm_eps).reshape(B, S, H * hd)
    out = layers.dense({"w": p["wo"]}, y * g)
    return out, x[:, -1, :], wkv_state


def channel_mix(p, x, cfg: ModelConfig, last_x):
    xs = _shift(x, last_x)
    mix = jax.nn.sigmoid(p["mix"].astype(jnp.float32))
    mk = mix[0].astype(x.dtype)
    mr = mix[1].astype(x.dtype)
    xk = x * mk + xs * (1 - mk)
    xr = x * mr + xs * (1 - mr)
    k = jnp.square(jax.nn.relu(layers.dense({"w": p["wk"]}, xk)))
    out = jax.nn.sigmoid(layers.dense({"w": p["wr"]}, xr)) * layers.dense({"w": p["wv"]}, k)
    return out, x[:, -1, :]


def init_rwkv_state(cfg: ModelConfig, batch: int):
    """Per-layer recurrent state pytree (stacked over layers by the model)."""
    d, H, hd = cfg.d_model, cfg.rwkv_heads, cfg.rwkv_head_dim
    return {
        "tm_x": jnp.zeros((batch, d), cfg.jnp_dtype),
        "cm_x": jnp.zeros((batch, d), cfg.jnp_dtype),
        "wkv": jnp.zeros((batch, H, hd, hd), jnp.float32),
    }
