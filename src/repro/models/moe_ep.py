"""Expert-parallel MoE via shard_map + explicit all-to-all (beyond-paper).

The default `moe.moe_mlp` keeps experts TP/FSDP-sharded and dispatches with
group-local capacity buffers — zero routing collectives, but every device
holds a slice of every expert. This module implements the classic
expert-parallel layout for models whose per-expert slab fits one device
(granite: 40 experts -> padded to 48, 3 per device at ~4.7 MB each):

  tokens sharded over the whole mesh -> local top-k routing -> per-peer
  capacity buffers -> all-to-all over the expert axis -> local expert FFNs
  -> all-to-all back -> local weighted combine.

Experts are padded to a multiple of the expert axis ("dead expert" slots
with -inf router logits) to handle E % axis != 0. The all-to-all traffic
(~2·T·k·d bytes/layer) surfaces in the dry-run's collective breakdown —
exactly the MoE roofline term the assignment calls out.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.6 moved shard_map out of experimental
    from jax import shard_map as _shard_map_mod
    shard_map = _shard_map_mod.shard_map if hasattr(_shard_map_mod, "shard_map") \
        else _shard_map_mod
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map

import inspect

# replication-check kwarg was renamed check_rep -> check_vma across jax
# versions; resolve once at import (same pattern as kernels' _CompilerParams)
_CHECK_KW = ("check_vma" if "check_vma"
             in inspect.signature(shard_map).parameters else "check_rep")

from repro.models.config import ModelConfig


def pad_experts(p, cfg: ModelConfig, axis_size: int):
    """Pad expert-stacked weights (E, ...) to a multiple of axis_size."""
    E = cfg.num_experts
    E_pad = -(-E // axis_size) * axis_size
    if E_pad == E:
        return p, E_pad
    pad = E_pad - E
    out = dict(p)
    for key in ("gate", "up", "down"):
        out[key] = jnp.pad(p[key], ((0, pad), (0, 0), (0, 0)))
    out["router"] = jnp.pad(p["router"], ((0, 0), (0, pad)))
    return out, E_pad


def moe_mlp_ep(p, x, cfg: ModelConfig, mesh, *, axis: str = "model",
               token_axes=("data", "model"), capacity_factor: float | None = None):
    """x: (B, S, d) -> (B, S, d). Expert weights in `p` must already be
    padded (pad_experts) and are sharded P(axis) on the expert dim. Tokens
    are flattened and sharded over `token_axes`; the all-to-all runs among
    the `axis` peers within each row of the other axes."""
    B, S, d = x.shape
    A = mesh.shape[axis]
    E = p["gate"].shape[0]
    assert E % A == 0, "pad_experts first"
    E_loc = E // A
    k = cfg.experts_per_token
    T = B * S
    n_shards = int(np.prod([mesh.shape[a] for a in token_axes]))
    assert T % n_shards == 0, (T, n_shards)
    T_loc = T // n_shards
    cf = capacity_factor or cfg.moe_capacity_factor
    # capacity per (source device, destination peer)
    C = max(1, int(np.ceil(T_loc * k / A * cf)))

    def device_fn(x_loc, router, gate_w, up_w, down_w):
        # x_loc (T_loc, d); router (d, E) replicated; weights (E_loc, d, ff)
        logits = x_loc.astype(jnp.float32) @ router.astype(jnp.float32)
        logits = jnp.where(jnp.arange(E)[None] < cfg.num_experts, logits, -1e30)
        gates, eidx = jax.lax.top_k(jax.nn.softmax(logits, -1), k)  # (T_loc,k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

        dest = (eidx // E_loc).reshape(T_loc * k)
        onehot = jax.nn.one_hot(dest, A, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - 1
        pos_in = jnp.take_along_axis(pos, dest[:, None], 1)[:, 0]
        keep = pos_in < C
        slot = jnp.where(keep, dest * C + pos_in, A * C)      # trash = A*C

        tok_of = jnp.broadcast_to(jnp.arange(T_loc)[:, None],
                                  (T_loc, k)).reshape(T_loc * k)
        send_tok = jnp.full((A * C + 1,), T_loc, jnp.int32).at[slot].set(
            tok_of, mode="drop")[: A * C]
        send_el = jnp.zeros((A * C + 1,), jnp.int32).at[slot].set(
            (eidx % E_loc).reshape(T_loc * k), mode="drop")[: A * C]
        x_pad = jnp.concatenate([x_loc, jnp.zeros((1, d), x_loc.dtype)], 0)
        send_x = x_pad[send_tok].reshape(A, C, d)
        send_el = send_el.reshape(A, C)
        send_ok = (send_tok < T_loc).reshape(A, C)

        # exchange: block i goes to peer i (tiled all-to-all over `axis`)
        a2a = lambda a: jax.lax.all_to_all(a, axis, 0, 0, tiled=True)
        recv_x = a2a(send_x).reshape(A * C, d)
        recv_el = a2a(send_el).reshape(A * C)
        recv_ok = a2a(send_ok).reshape(A * C)

        oh = (jax.nn.one_hot(recv_el, E_loc, dtype=jnp.float32)
              * recv_ok[:, None]).astype(recv_x.dtype)
        h = jnp.einsum("td,edf,te->tf", recv_x, gate_w, oh)
        h = jax.nn.silu(h) * jnp.einsum("td,edf,te->tf", recv_x, up_w, oh)
        out = jnp.einsum("tf,efd,te->td", h, down_w, oh)

        back = a2a(out.reshape(A, C, d)).reshape(A * C, d)    # to senders
        back = jnp.concatenate([back, jnp.zeros((1, d), back.dtype)], 0)
        per_choice = back[slot].reshape(T_loc, k, d)
        w = (gates * keep.reshape(T_loc, k)).astype(jnp.float32)
        return (per_choice.astype(jnp.float32) * w[..., None]).sum(1).astype(
            x_loc.dtype)

    tok_spec = P(tuple(token_axes), None)
    fn = shard_map(device_fn, mesh=mesh,
                   in_specs=(tok_spec, P(None, None),
                             P(axis, None, None), P(axis, None, None),
                             P(axis, None, None)),
                   out_specs=tok_spec, **{_CHECK_KW: False})
    out = fn(x.reshape(T, d), p["router"], p["gate"], p["up"], p["down"])
    return out.reshape(B, S, d)


def moe_ep_ref(p_padded, x, cfg: ModelConfig):
    """Single-device oracle with the same padded-expert routing semantics
    (top-k over padded logits, no capacity drops)."""
    B, S, d = x.shape
    E = p_padded["gate"].shape[0]
    k = cfg.experts_per_token
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p_padded["router"].astype(jnp.float32))
    logits = jnp.where(jnp.arange(E)[None, None] < cfg.num_experts,
                       logits, -1e30)
    gates, eidx = jax.lax.top_k(jax.nn.softmax(logits, -1), k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    h = jax.nn.silu(jnp.einsum("bsd,edf->bsef", x, p_padded["gate"]))
    h = h * jnp.einsum("bsd,edf->bsef", x, p_padded["up"])
    allout = jnp.einsum("bsef,efd->bsed", h, p_padded["down"]).astype(jnp.float32)
    sel = jnp.take_along_axis(allout, eidx[..., None], axis=2)
    return (sel * gates[..., None]).sum(2).astype(x.dtype)
