"""Mixture-of-Experts MLP with group-local capacity dispatch.

TPU adaptation (DESIGN.md §3): instead of a global sort / giant one-hot
dispatch tensor, tokens are split into G groups (G = data-parallel degree
when divisible, so each group is shard-local under pjit) and each group
dispatches into per-expert capacity buffers via int32 scatter/gather. This
is the classic GShard/Switch "dropping" formulation with *local* capacity:
static shapes, O(T·k + E·C·d) memory, and zero cross-shard traffic for
routing itself (expert weights are TP/FSDP-sharded, not expert-parallel,
because the assigned expert counts — 8, 40 — do not divide the 16-wide
model axis; see EXPERIMENTS.md §Perf for the shard_map expert-parallel
variant explored beyond the paper).

Tokens overflowing an expert's capacity are dropped (pass through the
residual only), standard for capacity-based MoE.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models.config import ModelConfig


def init_moe(rng, cfg: ModelConfig):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    dt = cfg.jnp_dtype
    ks = jax.random.split(rng, 4)
    def w(key, shape, scale=0.02):
        return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dt)
    return {
        "router": w(ks[0], (d, E)),
        "gate": w(ks[1], (E, d, ff)),
        "up": w(ks[2], (E, d, ff)),
        "down": w(ks[3], (E, ff, d), 0.02 / np.sqrt(2 * cfg.num_layers)),
    }


def _pick_groups(T: int, preferred: int) -> int:
    g = min(preferred, T)
    while T % g:
        g -= 1
    return max(g, 1)


def moe_mlp(p, x, cfg: ModelConfig, *, groups: int = 16):
    """x: (B, S, d) -> (B, S, d). groups should match the data-shard count so
    dispatch stays shard-local; any divisor of B*S works."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    G = _pick_groups(T, groups)
    Tg = T // G
    C = max(1, int(np.ceil(Tg * k / E * cfg.moe_capacity_factor)))
    C = min(C, Tg)

    xt = x.reshape(G, Tg, d)
    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32), p["router"].astype(jnp.float32))
    gates, eidx = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), k)  # (G,Tg,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)  # renormalize top-k

    # position of each (token, choice) within its expert's capacity buffer
    onehot = jax.nn.one_hot(eidx, E, dtype=jnp.int32)           # (G,Tg,k,E)
    flat_oh = onehot.reshape(G, Tg * k, E)
    pos = jnp.cumsum(flat_oh, axis=1) - 1                        # (G,Tg*k,E)
    pos_in_e = jnp.take_along_axis(pos, eidx.reshape(G, Tg * k, 1), axis=-1)[..., 0]
    keep = pos_in_e < C                                          # capacity drop
    e_flat = eidx.reshape(G, Tg * k)
    slot = jnp.where(keep, e_flat * C + pos_in_e, E * C)         # E*C = trash slot

    # scatter token ids into (E*C + 1) slots, then gather token features
    tok_of_choice = jnp.broadcast_to(jnp.arange(Tg)[None, :, None], (G, Tg, k)).reshape(G, Tg * k)
    buf = jnp.full((G, E * C + 1), Tg, jnp.int32)                # Tg = dummy token
    gi = jnp.arange(G)[:, None]
    buf = buf.at[gi, slot].set(tok_of_choice, mode="drop")
    sel = buf[:, : E * C].reshape(G, E, C)                       # token id per slot
    x_pad = jnp.concatenate([xt, jnp.zeros((G, 1, d), xt.dtype)], axis=1)
    ein = jnp.take_along_axis(x_pad[:, None], sel[..., None], axis=2)  # (G,E,C,d)

    # expert FFNs, batched over E
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", ein, p["gate"]))
    h = h * jnp.einsum("gecd,edf->gecf", ein, p["up"])
    eout = jnp.einsum("gecf,efd->gecd", h, p["down"])             # (G,E,C,d)

    # combine: route expert outputs back to tokens with gate weights
    eout_flat = eout.reshape(G, E * C, d)
    eout_flat = jnp.concatenate([eout_flat, jnp.zeros((G, 1, d), eout.dtype)], axis=1)
    per_choice = jnp.take_along_axis(eout_flat, slot[..., None], axis=1)  # (G,Tg*k,d)
    w = (gates.reshape(G, Tg * k) * keep).astype(jnp.float32)
    out = (per_choice.astype(jnp.float32) * w[..., None]).reshape(G, Tg, k, d).sum(2)
    return out.reshape(B, S, d).astype(x.dtype), _aux_loss(logits, eidx, E)


def _aux_loss(router_logits, eidx, E):
    """Switch-style load-balance auxiliary loss (mean over groups)."""
    probs = jax.nn.softmax(router_logits, axis=-1)               # (G,T,E)
    frac_tokens = jnp.mean(jax.nn.one_hot(eidx[..., 0], E), axis=1)  # top-1 assignment
    frac_probs = jnp.mean(probs, axis=1)
    return (E * jnp.sum(frac_tokens * frac_probs, axis=-1)).mean()


def moe_ref(p, x, cfg: ModelConfig):
    """Dense reference: every expert on every token (oracle for tests)."""
    B, S, d = x.shape
    k = cfg.experts_per_token
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"].astype(jnp.float32))
    gates, eidx = jax.lax.top_k(jax.nn.softmax(logits, -1), k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    h = jax.nn.silu(jnp.einsum("bsd,edf->bsef", x, p["gate"]))
    h = h * jnp.einsum("bsd,edf->bsef", x, p["up"])
    allout = jnp.einsum("bsef,efd->bsed", h, p["down"]).astype(jnp.float32)  # (B,S,E,d)
    sel = jnp.take_along_axis(allout, eidx[..., None], axis=2)   # (B,S,k,d)
    return (sel * gates[..., None]).sum(2).astype(x.dtype)
