"""Sharding rules: parameter/activation/cache PartitionSpecs (DESIGN §5).

2D layout, MaxText-style: "model" = tensor parallel (heads / d_ff / vocab),
"data" (+ "pod") = FSDP over the d_model-ish dim of weights and the batch dim
of activations. Specs are derived from parameter *path names* via ordered
regex rules; stacked-layer leading dims ((L,) or (L/2, 2)) get None padding
automatically by rank comparison.

Decode KV caches shard the *sequence* dim over "model" by default: the
assigned GQA configs have 4–8 kv heads, which do not divide the 16-wide
model axis, while 32k sequences always do. (Head-sharding for kv>=16 archs
is evaluated as a perf iteration — EXPERIMENTS.md §Perf.)
"""
from __future__ import annotations

import re

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig

# ordered (regex on "/".joined path, spec for the LOGICAL tensor)
_PARAM_RULES: list[tuple[str, P]] = [
    # embed/head: vocab over model, d_model REPLICATED — putting d over
    # "data" makes GSPMD resolve the embedding-gather conflict by
    # replicating the *batch* instead, which un-shards every activation
    # downstream (observed: 34 GB/dev attention scores in train_4k)
    (r"embed$", P("model", None)),                # (V, d): vocab TP
    (r"lm_head$", P(None, "model")),              # (d, V)
    (r"(wq|wk|wv)/w$", P("data", "model")),
    (r"(wq|wk|wv)/b$", P("model")),
    (r"wo/w$", P("model", "data")),
    (r"wo/b$", P("data")),
    (r"(gate|up)/w$", P("data", "model")),        # dense mlp
    (r"(gate|up)/b$", P("model")),
    (r"down/w$", P("model", "data")),
    (r"down/b$", P("data")),
    (r"moe/router$", P("data", None)),
    (r"moe/(gate|up)$", P(None, "data", "model")),  # (E, d, ff)
    (r"moe/down$", P(None, "model", "data")),       # (E, ff, d)
    # mamba2
    (r"in_proj/w$", P("data", "model")),
    (r"conv_w$", P(None, "model")),
    (r"conv_b$", P("model")),
    (r"(A_log|D|dt_bias)$", P("model")),
    (r"out_proj/w$", P("model", "data")),
    (r"mamba/norm/scale$", P("model")),
    # rwkv6
    (r"tm/(wr|wk|wv|wg)$", P("data", "model")),
    (r"tm/wo$", P("model", "data")),
    (r"tm/w_lora_a$", P("data", None)),
    (r"tm/w_lora_b$", P(None, "model")),
    (r"tm/u$", P("model", None)),
    (r"tm/(mix|w_base)$", P()),
    (r"tm/ln/scale$", P()),
    (r"cm/wk$", P("data", "model")),
    (r"cm/wv$", P("model", "data")),
    (r"cm/wr$", P("data", None)),
    (r"cm/mix$", P()),
    # norms & everything 1-D defaults to replicated
    (r".*", P()),
]


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


DEFAULT_MESH_SHAPE = {"data": 16, "model": 16}


def param_spec(path, leaf, mesh_shape: dict[str, int] | None = None) -> P:
    mesh_shape = mesh_shape or DEFAULT_MESH_SHAPE
    s = _path_str(path)
    for pat, spec in _PARAM_RULES:
        if re.search(pat, s):
            ndim = np.ndim(leaf) if not hasattr(leaf, "ndim") else leaf.ndim
            pad = ndim - len(spec)
            assert pad >= 0, f"spec {spec} longer than tensor rank for {s}"
            full = [None] * pad + list(spec)
            # axes that do not divide their dimension (e.g. granite's vocab
            # 49155 over 16-wide "model") are relocated to another dividing
            # dim, else dropped (replicated)
            shape = leaf.shape
            dropped = []
            for i, ax in enumerate(full):
                if ax is None:
                    continue
                size = mesh_shape.get(ax, 1)
                if shape[i] % size:
                    full[i] = None
                    dropped.append(ax)
            # embeddings stay replicated when vocab doesn't divide: sharding
            # the d_model dim instead trips an XLA SPMD gather-partitioner
            # verifier bug under autodiff (granite train, EXPERIMENTS.md)
            if not s.endswith("embed"):
                for ax in dropped:
                    for i, cur in enumerate(full):
                        if cur is None and shape[i] % mesh_shape.get(ax, 1) == 0 \
                                and shape[i] >= mesh_shape.get(ax, 1):
                            full[i] = ax
                            break
            return P(*full)
    raise AssertionError("unreachable")


def param_specs(params, mesh_shape: dict[str, int] | None = None,
                weight_mode: str = "fsdp_tp") -> dict:
    """Pytree of PartitionSpecs matching a params pytree.

    weight_mode:
      fsdp_tp    — 2D: d_model-ish over "data" + TP over "model" (training
                   default; minimal weight memory, per-layer all-gathers).
      tp_only    — drop the "data" axis from weights (replicate across data
                   rows). Serving mode: no optimizer state to hold, weights/
                   16 chips usually fit, and the per-step FSDP all-gather
                   traffic disappears (EXPERIMENTS.md §Perf).
      replicated — fully replicated weights (small models): batch-parallel
                   serving with zero weight collectives.
    """
    def spec(p, l):
        s = param_spec(p, l, mesh_shape)
        if weight_mode == "fsdp_tp":
            return s
        if weight_mode == "tp_only":
            return P(*[None if ax == "data" else ax for ax in s])
        return P(*([None] * len(s)))  # replicated

    return jax.tree_util.tree_map_with_path(spec, params)


# ---------------------------------------------------------------------------
# activations / inputs / caches
# ---------------------------------------------------------------------------

def dp_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


def batch_axis(batch: int, multi_pod: bool, mesh_shape: dict[str, int]):
    """Largest dp prefix that divides the batch (None if batch=1)."""
    axes = []
    prod = 1
    for a in dp_axes(multi_pod):
        if batch % (prod * mesh_shape[a]) == 0:
            axes.append(a)
            prod *= mesh_shape[a]
    return tuple(axes) if axes else None


def batch_spec(cfg: ModelConfig, shape_kind: str, batch: int, multi_pod: bool,
               mesh_shape: dict[str, int]) -> dict:
    """Input shardings for a workload batch dict."""
    b = batch_axis(batch, multi_pod, mesh_shape)
    specs = {"tokens": P(b, None)}
    if cfg.frontend == "vision":
        specs["frontend_embeds"] = P(b, None, None)
    if cfg.family == "audio":
        specs["frontend_embeds"] = P(b, None, None)
    if shape_kind == "train":
        specs["labels"] = P(b, None)
    return specs


def cache_specs(cfg: ModelConfig, cache, batch: int, multi_pod: bool,
                mesh_shape: dict[str, int], *, seq_shard: str | None = "model") -> dict:
    """PartitionSpecs for a decode cache pytree (by key name + rank)."""
    b = batch_axis(batch, multi_pod, mesh_shape)
    # with batch unshardable (long_500k, B=1) extend the seq sharding to dp too
    seq_axes: tuple = (seq_shard,) if seq_shard else ()
    if b is None:
        seq_axes = tuple(dp_axes(multi_pod)) + seq_axes

    def spec_for(key: str, leaf):
        C = leaf.shape
        if key == "pos" or key == "enc_lens":
            return P(None)
        if key in ("conv",):                 # (L, B, K-1, conv_dim)
            return P(None, b, None, "model")
        if key in ("ssm",):                  # (L, B, nh, hd, ns)
            return P(None, b, "model", None, None)
        if key in ("tm_x", "cm_x"):          # (L, B, d)
            return P(None, b, None)
        if key == "wkv":                     # (L, B, H, hd, hd)
            return P(None, b, "model", None, None)
        if key.startswith(("k", "v", "self_", "cross_", "attn_")):
            # (L, B, C, Hkv, hd): shard the sequence dim
            sa = seq_axes if seq_axes else None
            divisor = int(np.prod([mesh_shape[a] for a in (seq_axes or ())]))
            if divisor and C[2] % max(divisor, 1) == 0 and C[2] >= max(divisor, 1):
                return P(None, b, (sa if isinstance(sa, tuple) else sa), None, None)
            return P(None, b, None, None, None)
        return P(*([None] * leaf.ndim))

    return {k: spec_for(k, v) for k, v in cache.items()}
