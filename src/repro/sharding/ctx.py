"""Logical activation-sharding annotations (MaxText-style).

Models call ``constrain(x, "act_btd")`` at a few key points (embedding
output, layer-scan carry, logits). Outside a mesh context this is a no-op,
so engine/smoke-test code paths are untouched; the dry-run/launchers
activate a mapping from logical names to PartitionSpecs.

Why needed: GSPMD's gather heuristic resolves the vocab-sharded embedding
lookup by replicating the *batch*, which silently un-shards every
downstream activation (observed as 34 GB/dev attention scores in train_4k).
One constraint at the embedding output pins the batch axis.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


@contextlib.contextmanager
def activate(mapping: dict[str, P]):
    prev = getattr(_state, "mapping", None)
    _state.mapping = mapping
    try:
        yield
    finally:
        _state.mapping = prev


def constrain(x, name: str):
    mapping = getattr(_state, "mapping", None)
    if not mapping or name not in mapping:
        return x
    spec = mapping[name]
    if spec is None:
        return x
    # pad the spec to the array rank (named specs are for the trailing dims)
    pad = x.ndim - len(spec)
    if pad < 0:
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec, *([None] * pad)))


def standard_mapping(batch_axes) -> dict[str, P]:
    """batch_axes: tuple of mesh axes for the global-batch dim (or None)."""
    b = batch_axes
    return {
        "act_btd": P(b, None, None),   # (batch, seq, d_model)
        "logits_btv": P(b, None, "model"),
        "act_bd": P(b, None),
    }
