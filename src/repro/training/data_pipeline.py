"""Token data pipeline: deterministic synthetic corpus -> packed LM batches.

Offline container: no real corpora, so documents are sampled from a
Zipf-distributed unigram model with Markov structure (enough signal for a
~100M-param model to visibly learn in a few hundred steps, which is what the
end-to-end train example demonstrates). Sequences are packed to fixed length
with cross-document attention left in (llama-style packing)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    zipf_a: float = 1.2
    markov_order: int = 1
    doc_len_mean: int = 512


class SyntheticCorpus:
    """Markov chain over a Zipf vocabulary — learnable structure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V = cfg.vocab_size
        # sparse transition structure: each token prefers a few successors
        self.n_succ = 8
        self.succ = rng.integers(0, V, size=(V, self.n_succ))
        self.succ_p = rng.dirichlet(np.ones(self.n_succ) * 0.5, size=V)
        base = 1.0 / np.power(np.arange(1, V + 1), cfg.zipf_a)
        self.base_p = base / base.sum()
        self.rng = rng

    def document(self) -> np.ndarray:
        n = max(8, int(self.rng.exponential(self.cfg.doc_len_mean)))
        out = np.empty(n, np.int32)
        tok = int(self.rng.choice(self.cfg.vocab_size, p=self.base_p))
        for i in range(n):
            out[i] = tok
            if self.rng.random() < 0.9:  # follow the chain
                j = int(self.rng.choice(self.n_succ, p=self.succ_p[tok]))
                tok = int(self.succ[tok, j])
            else:  # jump
                tok = int(self.rng.choice(self.cfg.vocab_size, p=self.base_p))
        return out


def packed_batches(cfg: DataConfig, num_batches: int) -> Iterator[dict]:
    """Yields {"tokens": (B,S) int32, "labels": (B,S) int32} LM batches."""
    corpus = SyntheticCorpus(cfg)
    need = cfg.batch_size * (cfg.seq_len + 1)
    buf = np.empty(0, np.int32)
    for _ in range(num_batches):
        while buf.size < need:
            buf = np.concatenate([buf, corpus.document()])
        chunk, buf = buf[:need], buf[need:]
        arr = chunk.reshape(cfg.batch_size, cfg.seq_len + 1)
        yield {"tokens": arr[:, :-1].copy(), "labels": arr[:, 1:].copy()}
