"""AdamW optimizer + LR schedules (no external deps — built in JAX).

Moments are kept in f32 regardless of param dtype; state shards identically
to its parameter (launch/sharding.py maps the same PartitionSpec)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    moments_dtype: str = "float32"  # "bfloat16" halves optimizer HBM
    #                                 (mixtral train: 5.7 -> 3.4 GB/dev args)


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def lr_at(cfg: AdamWConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params, moments_dtype: str = "float32") -> OptState:
    dt = jnp.dtype(moments_dtype)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(cfg: AdamWConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, stats)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        mdt = m.dtype
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m.astype(mdt), v.astype(mdt))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
