"""Minimal-dependency checkpointing: params/opt-state pytrees -> .npz.

Flat key = "/".join(path). Restores onto a like-structured pytree (shapes
and dtypes must match), so it composes with sharded params via
jax.device_get / device_put at the call site."""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V":  # ml_dtypes (bfloat16): store upcast to f32
            arr = np.asarray(jnp.asarray(leaf).astype(jnp.float32))
        flat[key] = arr
    return flat


def save(path: str, params, opt_state=None, step: int | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload = {f"p:{k}": v for k, v in _flatten(params).items()}
    if opt_state is not None:
        payload.update({f"o:{k}": v for k, v in _flatten(opt_state).items()})
    if step is not None:
        payload["meta:step"] = np.asarray(step)
    np.savez(path, **payload)


def restore(path: str, params_like, opt_like=None):
    """Returns (params, opt_state|None, step|None) with ``*_like`` structure."""
    data = np.load(path)

    def fill(tree, prefix):
        flat = _flatten(tree)
        out = {}
        for k in flat:
            key = f"{prefix}:{k}"
            if key not in data:
                raise KeyError(f"checkpoint missing {key}")
            if data[key].shape != flat[k].shape:
                raise ValueError(f"shape mismatch for {key}: "
                                 f"{data[key].shape} vs {flat[k].shape}")
            out[k] = data[key]
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        keys = list(_flatten(tree))
        # cast via jnp: numpy lacks cast kernels for ml_dtypes (bfloat16)
        return treedef.unflatten(
            [jnp.asarray(out[k]).astype(l.dtype) for k, l in zip(keys, leaves)])

    params = fill(params_like, "p")
    opt = fill(opt_like, "o") if opt_like is not None else None
    step = int(data["meta:step"]) if "meta:step" in data else None
    return params, opt, step
