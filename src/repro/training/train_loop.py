"""Training step + loop (the train_4k workload shape).

``make_train_step(model, opt_cfg)`` builds the pure function lowered by the
multi-pod dry-run; ``train`` runs it for real on CPU for the examples."""
from __future__ import annotations

import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.training.optimizer import AdamWConfig, OptState, adamw_update, init_opt_state


def make_train_step(model, opt_cfg: AdamWConfig, *, microbatches: int = 1) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    microbatches > 1 runs gradient accumulation over a lax.scan: activation
    memory scales with global_batch/microbatches (needed to fit train_4k in
    16 GB/chip HBM), grads accumulate in f32.
    """

    def train_step(params, opt_state: OptState, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
        else:
            mb = jax.tree.map(
                lambda a: a.reshape(microbatches, a.shape[0] // microbatches,
                                    *a.shape[1:]), batch)

            def step(acc, b):
                l, g = jax.value_and_grad(model.loss)(params, b)
                acc = jax.tree.map(lambda a, x: a + x.astype(jnp.float32),
                                   acc, (l, g))
                return acc, None

            zero = (jnp.zeros((), jnp.float32),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (loss, grads), _ = jax.lax.scan(step, zero, mb)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        params, opt_state, stats = adamw_update(opt_cfg, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **stats}

    return train_step


def train(model, params, batches, opt_cfg: AdamWConfig | None = None,
          *, log_every: int = 10, checkpoint_fn=None, checkpoint_every: int = 0):
    """Run the jitted train loop over an iterable of batches (CPU-scale)."""
    opt_cfg = opt_cfg or AdamWConfig()
    step_fn = jax.jit(make_train_step(model, opt_cfg))
    opt_state = init_opt_state(params)
    history = []
    t0 = time.perf_counter()
    for i, batch in enumerate(batches):
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if i % log_every == 0 or i == 0:
            loss = float(metrics["loss"])
            history.append((i, loss))
            print(f"step {i:5d}  loss {loss:.4f}  gnorm "
                  f"{float(metrics['grad_norm']):.3f}  lr {float(metrics['lr']):.2e}  "
                  f"({time.perf_counter() - t0:.1f}s)")
        if checkpoint_fn and checkpoint_every and i and i % checkpoint_every == 0:
            checkpoint_fn(params, opt_state, i)
    return params, opt_state, history
