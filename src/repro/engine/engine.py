"""Serving engine: continuous batching + paged KV + layer-interruptible prefill.

One ``ServingEngine`` is an xllm-instance analogue (DESIGN §3): it holds the
model weights once and can run Prefill and/or Decode iterations. The paper's
two mechanisms are implemented for real, not simulated:

* **Chunked prefill + fused mixed steps** (§3.4.1 boundary granularity):
  ``mixed_step(decode_rids, prefill_rid, chunk_tokens)`` advances a prompt by
  a token-budgeted chunk INSIDE the same jitted dispatch that decodes the
  resident batch. The chunk's K/V scatters into the donated paged pools
  first, then the (length-bucketed) query block attends over the request's
  gathered pages — everything already landed plus itself — with causal
  ``q_offset``/per-row ``kv_lens`` masking, so one trace serves every
  (chunk length, context) bucket. Between chunks the only state is the
  count of landed tokens (``ChunkedPrefill``): pausing costs nothing and a
  resume re-runs no layer. Decode-side attention keeps the backend paged
  kernel dispatch; the chunk side uses the XLA flash path on every backend
  (the Pallas prefill kernel's offsets are compile-time — see ROADMAP).
* **Layer-level interruption** (§3.4.1, legacy path): whole-prompt
  ``prefill()`` executes as a sequence of per-layer jitted calls carrying
  the hidden state; between layers the engine polls a preemption callback.
  An interrupted prefill keeps (hidden, layer index, KV-so-far) and resumes
  exactly where it stopped — tests assert bit-compatible logits vs an
  uninterrupted run. Prompts are padded to power-of-two buckets (masked via
  ``kv_lens``) so arbitrary lengths stop retracing the layer functions.
* **Mix decoding selection** (§3.4.4): each decode iteration builds its batch
  with ``core.scheduling.mix_decoding_selection`` under the TPOT SLO using
  the roofline perf model.

Decode batches are padded to bucket sizes (TPU/XLA static shapes, DESIGN §3).
Supported families here: dense + MoE with a single attention window (the
cluster-scale behaviour of every family is exercised via the simulator).

Engine hot path & attention backends
------------------------------------
The per-iteration hot path is allocation- and sync-free:

* ``backend="auto"|"pallas"|"interpret"|"ref"`` selects the attention
  implementation everywhere (prefill flash + paged decode attention).
  ``auto`` resolves to the Pallas TPU kernels when a TPU is attached and to
  the XLA/jnp reference path on CPU; ``interpret`` runs the Pallas kernel
  bodies on any backend (parity/debug). Threaded through ``CoLocatedServer``
  and ``launch.serve --backend``.
* ``k_pool``/``v_pool`` are **donated** through the jitted decode step and
  through the prefill KV scatter, so XLA writes the paged pools in place
  instead of copying the full (L, num_pages, page, Hkv, hd) arrays every
  iteration. Prefill buffers each layer's K/V and lands the whole prefill
  in a single donated scatter (one more at each preemption point).
* Sampling (greedy, or temperature/top-k via ``SamplingParams`` /
  ``set_sampling``) runs **inside** the jitted decode step — only the (B,)
  next-token ids cross the device boundary, never (B, vocab) logits.
* Per-layer parameters are pre-sliced once at construction; per-step token
  bookkeeping uses preallocated numpy rings (``TokenRing``), not Python
  lists.
* **Multi-step decode horizons**: ``decode_horizon(rids, K)`` fuses K
  consecutive decode iterations into ONE jitted dispatch — a
  ``jax.lax.scan`` over the same per-step core as ``decode_step``, with the
  sampled token fed back on-device, positions/lengths advanced inside the
  scan, pages claimed ahead so no request runs off its block table
  mid-horizon, and early-exit masking for rows that hit ``max_new_tokens``.
  One (K, B) token block crosses the device boundary per horizon instead of
  one (B,) sync per token; ``PerfModel.suggest_decode_horizon`` picks K.
* **Fused mixed horizons**: ``mixed_horizon(rids, prid, chunk_tokens, K)``
  runs K fused mixed iterations in one scan — each iteration lands a
  ``chunk_tokens / K`` sub-chunk slice of the pending prefill while
  decoding the residents, sharing ``_mixed_core`` with ``mixed_step`` so
  the per-step math is bit-identical. Pages for the whole chunk AND K
  decode tokens per resident are claimed before the dispatch;
  ``PerfModel.suggest_mixed_horizon`` picks K under the §3.4.1
  horizon-boundary preemption bound.

``benchmarks/bench_decode_hotpath.py`` measures steps/s and host overhead
per step and verifies pool donation from the lowered HLO;
``BENCH_engine.json`` records the baseline→after throughput trajectory.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.perf_model import PerfModel
from repro.core.request import Phase, Request
from repro.core.scheduling import split_chunk
from repro.engine.kv_cache import PagedKVCache, transfer_checksum, verify_transfer


class EngineCrashedError(RuntimeError):
    """Raised when a dispatch is attempted on a crashed engine."""
from repro.kernels import backend_flags, resolve_backend
from repro.kernels.paged_attention.ops import paged_attention
from repro.models import attention, layers, moe as moe_lib
from repro.models.attention import impl_for_backend
from repro.models.transformer import Transformer, _norm


@dataclass
class SamplingParams:
    """Engine-default sampling. ``temperature <= 0`` means greedy; ``top_k``
    0 keeps the full vocab. Per-request overrides via ``set_sampling``."""
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0


def sample_tokens(logits, key, temps, top_ks):
    """On-device sampler: greedy rows where temps <= 0, temperature/top-k
    elsewhere. logits (B, V) f32; temps (B,) f32; top_ks (B,) int32
    (0 = full vocab). Returns (B,) int32 token ids."""
    V = logits.shape[-1]
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    srt = jnp.sort(logits, axis=-1)[:, ::-1]
    k_eff = jnp.clip(jnp.where(top_ks > 0, top_ks, V), 1, V)
    thresh = jnp.take_along_axis(srt, (k_eff - 1)[:, None], axis=-1)
    masked = jnp.where(logits >= thresh, logits, -jnp.inf)
    scaled = masked / jnp.maximum(temps, 1e-6)[:, None]
    sampled = jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
    return jnp.where(temps > 0, sampled, greedy)


class TokenRing:
    """Preallocated int32 token buffer (prompt + generated) with list-like
    reads. Appends write into preallocated storage (amortized O(1), no
    per-token Python list growth); capacity doubles if exceeded."""

    __slots__ = ("_buf", "_n")

    def __init__(self, tokens, capacity: int = 0):
        tokens = np.asarray(list(tokens), np.int32)
        cap = max(capacity, tokens.shape[0], 8)
        self._buf = np.empty(cap, np.int32)
        self._buf[: tokens.shape[0]] = tokens
        self._n = tokens.shape[0]

    def append(self, tok: int) -> None:
        if self._n == self._buf.shape[0]:
            grown = np.empty(self._buf.shape[0] * 2, np.int32)
            grown[: self._n] = self._buf
            self._buf = grown
        self._buf[self._n] = tok
        self._n += 1

    def tolist(self) -> list[int]:
        return self._buf[: self._n].tolist()

    def __len__(self) -> int:
        return self._n

    def __getitem__(self, i):
        if isinstance(i, slice):
            return self._buf[: self._n][i].tolist()
        n = self._n
        if not -n <= i < n:
            raise IndexError(i)
        return int(self._buf[i % n if i < 0 else i])

    def __iter__(self):
        return iter(self.tolist())

    def __eq__(self, other) -> bool:
        if isinstance(other, TokenRing):
            return self.tolist() == other.tolist()
        if isinstance(other, (list, tuple)):
            return self.tolist() == list(other)
        return NotImplemented

    def __repr__(self) -> str:
        return f"TokenRing({self.tolist()})"


@dataclass
class PartialPrefill:
    """State of a layer-interrupted prefill (resume token). KV of completed
    layers is already flushed to the paged pool (one donated scatter per
    interruption segment)."""
    rid: int
    x: jnp.ndarray            # hidden after `layer` layers, (1, S, d)
    layer: int                # layers completed
    tokens: np.ndarray


@dataclass
class ChunkedPrefill:
    """State of a chunk-granular prefill: ``done`` prompt tokens have run
    through EVERY layer and their KV is landed in the paged pool, so a
    resume costs nothing but the next chunk — no layer re-execution
    (contrast ``PartialPrefill``, which holds a mid-stack hidden state)."""
    rid: int
    tokens: np.ndarray        # full prompt token ids
    done: int = 0             # tokens landed (all layers, KV in the pool)
    cached: int = 0           # leading tokens claimed from the prefix cache
                              # (block-aligned; counted in `done` but never
                              # computed here — aborts must not bill them)


@dataclass
class EngineStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0
    preemptions: int = 0
    evictions: int = 0
    decode_steps: int = 0
    prefill_chunks: int = 0   # chunk-granular prefill dispatches
    mixed_steps: int = 0      # fused prefill-chunk + decode dispatches
    host_syncs: int = 0       # device->host syncs on the token path
                              # (one per dispatch that returns tokens)
    horizon_steps: int = 0    # decode iterations run inside K>1 horizons
    prefill_seconds: float = 0.0
    decode_seconds: float = 0.0
    prefix_hits: int = 0      # prompts that claimed >= 1 cached prefix page
    cached_tokens: int = 0    # prompt tokens served from the prefix cache
    shared_pages: int = 0     # pages claimed via refcount bumps, cumulative
    # dispatch counts per kind — makes amortization observable directly
    # (e.g. mixed_horizon dispatches each cover K steps + K sub-chunks),
    # not just via the host_syncs aggregate
    dispatches_by_kind: dict = field(default_factory=lambda: {
        "prefill": 0, "decode": 0, "mixed": 0, "horizon": 0,
        "mixed_horizon": 0})


class ServingEngine:
    def __init__(self, model: Transformer, params, *, num_pages: int = 512,
                 page_size: int = 16, decode_buckets: tuple[int, ...] = (1, 2, 4, 8, 16, 32),
                 perf_model: PerfModel | None = None, backend: str = "auto",
                 sampling: SamplingParams | None = None,
                 prefix_cache: bool = False,
                 kernels_from: "ServingEngine | None" = None):
        cfg = model.cfg
        assert not cfg.local_global and not cfg.sliding_window, \
            "engine supports full-attention archs (cluster-scale behaviour of " \
            "windowed/SSM families is exercised via the simulator)"
        self.model = model
        self.cfg = cfg
        self.params = params
        self.backend = resolve_backend(backend)
        self.sampling = sampling or SamplingParams()
        self.cache = PagedKVCache(cfg, num_pages, page_size,
                                  enable_prefix_cache=prefix_cache)
        self.decode_buckets = tuple(sorted(decode_buckets))
        self.perf_model = perf_model
        self.requests: dict[int, Request] = {}
        self.token_buf: dict[int, TokenRing] = {}   # prompt + generated tokens
        self.partial: dict[int, PartialPrefill] = {}
        self.chunk_state: dict[int, ChunkedPrefill] = {}
        self.req_sampling: dict[int, tuple[float, int]] = {}
        # Length bucketing (padding + per-row kv_lens masking) needs dynamic
        # key masks: the XLA flash path honors them; the Pallas kernel's
        # kv_len is compile-time, so those backends keep exact shapes.
        self._prefill_bucketed = impl_for_backend(self.backend) == "xla"
        self.stats = EngineStats()
        if kernels_from is not None:
            # Pool runtimes run N+M engines over the SAME weights; the jitted
            # step functions only close over (model, cfg, page_size, backend),
            # so sibling engines can share one compiled-kernel set instead of
            # re-tracing/compiling per engine.
            src = kernels_from
            assert (src.model is model and src.params is params
                    and src.cache.page_size == page_size
                    and src.backend == self.backend), \
                "kernel sharing requires identical model/params/page_size/backend"
            self._layer_fn = src._layer_fn
            self._embed_fn = src._embed_fn
            self._logits_fn = src._logits_fn
            self._sample_fn = src._sample_fn
            self._decode_fns = src._decode_fns
            self._mixed_fns = src._mixed_fns
            self._horizon_fns = src._horizon_fns
            self._mixed_horizon_fns = src._mixed_horizon_fns
            self._layer_params_cached = src._layer_params_cached
        else:
            self._layer_fn = self._build_layer_fn()
            self._embed_fn = jax.jit(lambda p, t: model._embed(p, t))
            self._logits_fn = jax.jit(lambda p, x: model._logits(p, x))
            self._sample_fn = jax.jit(sample_tokens)
            self._decode_fns: dict[tuple[int, int], Callable] = {}
            self._mixed_fns: dict[tuple, Callable] = {}
            self._horizon_fns: dict[tuple, Callable] = {}
            self._mixed_horizon_fns: dict[tuple, Callable] = {}
            # per-layer params sliced once (not jax.tree.map per layer per prefill)
            self._layer_params_cached = [
                jax.tree.map(lambda a, i=i: a[i], params["layers"])
                for i in range(cfg.num_layers)]
        self._base_key = jax.random.PRNGKey(self.sampling.seed)
        self._sample_step = 0
        self.alive = True

    # ------------------------------------------------------------------
    # fault surface
    # ------------------------------------------------------------------
    def crash(self) -> None:
        """Simulate an engine-process crash: device KV pools, block tables,
        and host-side request bookkeeping are all lost. Any further dispatch
        raises ``EngineCrashedError``. Recovery is the scheduler's job — the
        pool runtime re-admits every in-flight request from its frontend
        request log through the recompute path (greedy requests regenerate
        bit-identical token streams; see ``PoolRuntime._crash_engine``)."""
        self.alive = False
        self.requests.clear()
        self.token_buf.clear()
        self.partial.clear()
        self.chunk_state.clear()
        self.req_sampling.clear()
        self.cache.tables.clear()
        self.cache.lengths.clear()
        if self.cache.prefix is not None:
            # the radix tree indexes pool pages that no longer exist —
            # simply dropped; recovery recomputes (token parity holds)
            self.cache.prefix.clear()

    def _check_alive(self) -> None:
        if not self.alive:
            raise EngineCrashedError("engine has crashed; state is gone")

    # ------------------------------------------------------------------
    # sampling
    # ------------------------------------------------------------------
    def set_sampling(self, rid: int, temperature: float, top_k: int = 0) -> None:
        """Per-request override of the engine-default sampling params."""
        self.req_sampling[rid] = (temperature, top_k)

    def _sampling_arrays(self, rids: list[int], pad_to: int):
        d = (self.sampling.temperature, self.sampling.top_k)
        temps = np.zeros(pad_to, np.float32)
        topks = np.zeros(pad_to, np.int32)
        for i, r in enumerate(rids):
            temps[i], topks[i] = self.req_sampling.get(r, d)
        return temps, topks

    def _next_key(self):
        self._sample_step += 1
        return self._base_key, np.int32(self._sample_step)

    def _next_key_block(self, n: int):
        """Reserve ``n`` consecutive sample steps for a multi-step horizon.
        Returns (key, first_step); step t of the horizon folds in
        ``first_step + t`` — exactly the step ids n serial ``_next_key``
        calls would have produced, so K-step horizons sample bit-identically
        to K serial decode steps."""
        first = np.int32(self._sample_step + 1)
        self._sample_step += n
        return self._base_key, first

    # ------------------------------------------------------------------
    # layer-interruptible prefill
    # ------------------------------------------------------------------
    @staticmethod
    def pad_chunk(n: int) -> int:
        """Bucket a prefill prompt/chunk length to the next power of two
        (min 8) — bounds the jit trace count over arbitrary lengths the way
        ``pad_pages`` bounds the decode-table variants."""
        return max(8, 1 << (max(n, 1) - 1).bit_length())

    def _build_layer_fn(self):
        cfg = self.cfg
        impl = impl_for_backend(self.backend)

        @jax.jit
        def layer_fn(lp, x, positions, kv_lens):
            h = _norm(cfg, lp["ln1"], x)
            a, (k, v) = attention.attn_prefill(
                lp["attn"], h, positions, cfg, window=cfg.sliding_window,
                kv_lens=kv_lens, impl=impl)
            if cfg.use_post_norm:
                a = _norm(cfg, lp["post_ln1"], a)
            x = x + a
            h = _norm(cfg, lp["ln2"], x)
            if cfg.is_moe:
                m, _ = moe_lib.moe_mlp(lp["moe"], h, cfg, groups=1)
            else:
                m = layers.mlp(lp["mlp"], h, cfg.mlp_act)
            if cfg.use_post_norm:
                m = _norm(cfg, lp["post_ln2"], m)
            return x + m, k, v

        return layer_fn

    def _layer_params(self, i: int):
        return self._layer_params_cached[i]

    def add_request(self, req: Request, prompt_tokens: list[int]) -> None:
        self._check_alive()
        assert len(prompt_tokens) == req.prompt_len
        self.requests[req.rid] = req
        self.token_buf[req.rid] = TokenRing(
            prompt_tokens, capacity=req.prompt_len + req.output_len + 8)

    def _flush_prefill_kv(self, rid: int, start_layer: int, ks, vs) -> None:
        """Land buffered per-layer K/V in one donated scatter."""
        if ks:
            self.cache.write_prefill_layers(
                rid, start_layer, jnp.stack(ks), jnp.stack(vs))

    def prefill(self, rid: int, *, should_preempt: Callable[[], bool] | None = None,
                max_new_pages: bool = True) -> str:
        """Run (or resume) prefill for one request, checking the preemption
        callback between transformer layers. Returns "done" | "preempted"."""
        self._check_alive()
        # the legacy layer-granular path writes the WHOLE table via
        # write_prefill_layers — it must never run over a warm prefix claim
        # (that would overwrite pages shared with sibling requests)
        assert rid not in self.chunk_state, \
            "legacy prefill() cannot resume a chunked/warm-started request"
        t0 = time.perf_counter()
        req = self.requests[rid]
        cfg = self.cfg
        if rid in self.partial:
            part = self.partial.pop(rid)
            x, start_layer, tokens = part.x, part.layer, part.tokens
        else:
            tokens = np.asarray(self.token_buf[rid][: req.prompt_len], np.int32)
            self.cache.ensure(rid, req.prompt_len)
            padded = tokens
            if self._prefill_bucketed:
                # pad to a bucket length; the padded keys are masked out by
                # kv_lens below, so one trace serves every length in the
                # bucket instead of retracing per unique prompt length
                padded = np.zeros(self.pad_chunk(tokens.shape[0]), np.int32)
                padded[: tokens.shape[0]] = tokens
            x = self._embed_fn(self.params, jnp.asarray(padded)[None])
            start_layer = 0
        S = tokens.shape[0]
        positions = jnp.arange(x.shape[1])[None]
        kv_lens = jnp.asarray([S], jnp.int32)
        req.phase = Phase.PREFILLING
        ks, vs = [], []   # per-layer KV buffered; flushed once per segment
        for li in range(start_layer, cfg.num_layers):
            x, k, v = self._layer_fn(self._layer_params(li), x, positions,
                                     kv_lens)
            ks.append(k[0, :S])
            vs.append(v[0, :S])
            req.prefill_layers_done = li + 1
            if should_preempt is not None and li < cfg.num_layers - 1 and should_preempt():
                self._flush_prefill_kv(rid, start_layer, ks, vs)
                self.partial[rid] = PartialPrefill(rid, x, li + 1, tokens)
                self.stats.preemptions += 1
                self.stats.prefill_seconds += time.perf_counter() - t0
                return "preempted"
        self._flush_prefill_kv(rid, start_layer, ks, vs)
        # first token from the last REAL hidden state, sampled on device
        logits = self._logits_fn(self.params, x[:, S - 1])
        temps, topks = self._sampling_arrays([rid], 1)
        if temps[0] > 0:
            key, step = self._next_key()
            nxt = int(self._sample_fn(logits, jax.random.fold_in(key, step),
                                      jnp.asarray(temps), jnp.asarray(topks))[0])
        else:
            nxt = int(jnp.argmax(logits, -1)[0])
        self.token_buf[rid].append(nxt)
        req.generated = 1
        req.phase = Phase.DECODING
        self.stats.prefill_tokens += S
        self.stats.host_syncs += 1
        self.stats.dispatches_by_kind["prefill"] += 1
        self.stats.prefill_seconds += time.perf_counter() - t0
        return "done"

    def abort_prefill(self, rid: int) -> None:
        """Discard partial prefill state — layer-granular (whole prompt is
        re-run later, the pessimistic legacy accounting) or chunk-granular
        (only the tokens actually landed count as recompute waste)."""
        part = self.partial.pop(rid, None)
        state = self.chunk_state.pop(rid, None)
        self.cache.free(rid)
        req = self.requests[rid]
        if state is not None:
            # cached tokens were claimed from the prefix tree, not computed
            # here — losing them wastes no FLOPs
            req.recompute_tokens += state.done - state.cached
        elif part is not None:
            req.recompute_tokens += req.prompt_len
        # neither: nothing was computed yet -> nothing wasted
        req.prefill_layers_done = 0
        req.prefill_tokens_done = 0
        req.cached_tokens = 0
        req.phase = Phase.QUEUED

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def _bucket(self, n: int) -> int:
        for b in self.decode_buckets:
            if n <= b:
                return b
        return self.decode_buckets[-1]

    @staticmethod
    def pad_pages(pages: int) -> int:
        """Pad a decode batch's page dimension to a power of two — bounds the
        set of (bucket, pages) jit variants. Shared with the benchmarks."""
        return 1 << (pages - 1).bit_length()

    def _decode_core(self):
        """One decode iteration over the layer stack — the computation
        SHARED by the plain jitted step and the K-step horizon scan, so the
        two paths are token-identical by construction. Returns
        ``core(params, tokens, positions, tables, lengths, page_ids, offs,
        k_pool, v_pool) -> (logits, k_pool, v_pool)``."""
        cfg = self.cfg
        model = self.model
        use_ref, interpret = backend_flags(self.backend)
        hd = cfg.head_dim_

        def core(params, tokens, positions, tables, lengths, page_ids, offs,
                 k_pool, v_pool):
            x = model._embed(params, tokens[:, None])

            # The pools ride in the scan CARRY (not xs/ys): per-layer writes
            # are dynamic-update-slices into the carried buffer, which XLA
            # keeps in place inside the loop and aliases to the donated
            # inputs — the xs/ys formulation forced a full-pool copy per
            # step because ys are always freshly stacked.
            def body(carry, inp):
                x, kpool, vpool = carry
                lp, li = inp
                h = _norm(cfg, lp["ln1"], x)
                k_new, v_new = attention.project_kv_for_cache(lp["attn"], h, positions, cfg)
                # round through cfg dtype, then store in the pool's storage
                # dtype (f32 on CPU — see PagedKVCache) for bit-parity with
                # the native-dtype pool layout
                kpool = kpool.at[li, page_ids, offs].set(
                    k_new[:, 0].astype(cfg.jnp_dtype).astype(kpool.dtype))
                vpool = vpool.at[li, page_ids, offs].set(
                    v_new[:, 0].astype(cfg.jnp_dtype).astype(vpool.dtype))
                q = layers.dense(lp["attn"]["wq"], h[:, 0]).reshape(
                    -1, cfg.num_heads, hd)
                if cfg.qk_norm:
                    q = layers.rmsnorm(lp["attn"]["q_norm"], q, cfg.norm_eps)
                q = layers.apply_rope(q[:, None], positions[:, None], cfg.rope_theta)[:, 0]
                # compact the layer's KV to just this batch's pages: a gather
                # of B*P pages (+ renumbered tables) instead of slicing the
                # full num_pages pool out of the carried buffer per layer
                B, P = tables.shape
                page = kpool.shape[2]
                comp_k = kpool[li, tables].reshape(B * P, page, *kpool.shape[3:])
                comp_v = vpool[li, tables].reshape(B * P, page, *vpool.shape[3:])
                local_tables = jnp.arange(B * P, dtype=jnp.int32).reshape(B, P)
                a = paged_attention(q, comp_k, comp_v, local_tables, lengths,
                                    num_kv_heads=cfg.num_kv_heads,
                                    logit_softcap=cfg.attn_logit_softcap,
                                    use_ref=use_ref, interpret=interpret)
                a = layers.dense(lp["attn"]["wo"], a.reshape(a.shape[0], 1, -1))
                if cfg.use_post_norm:
                    a = _norm(cfg, lp["post_ln1"], a)
                x = x + a
                h = _norm(cfg, lp["ln2"], x)
                if cfg.is_moe:
                    m, _ = moe_lib.moe_mlp(lp["moe"], h, cfg, groups=1)
                else:
                    m = layers.mlp(lp["mlp"], h, cfg.mlp_act)
                if cfg.use_post_norm:
                    m = _norm(cfg, lp["post_ln2"], m)
                return (x + m, kpool, vpool), None

            (x, k_pool, v_pool), _ = jax.lax.scan(
                body, (x, k_pool, v_pool),
                (params["layers"], jnp.arange(cfg.num_layers)))
            return model._logits(params, x[:, 0]), k_pool, v_pool

        return core

    def _decode_fn(self, bucket: int, pages: int, sampled: bool = False):
        """``sampled=False`` specializes the step to plain argmax — the
        all-greedy default never pays the sampler's full-vocab sort."""
        key = (bucket, pages, sampled)
        if key in self._decode_fns:
            return self._decode_fns[key]
        core = self._decode_core()
        page_size = self.cache.page_size

        @functools.partial(jax.jit, donate_argnums=(5, 6))
        def step(params, tokens, positions, tables, lengths, k_pool, v_pool,
                 key, sample_step, temps, top_ks):
            page_ids = jnp.take_along_axis(
                tables, (positions // page_size)[:, None], axis=1)[:, 0]
            offs = positions % page_size
            logits, k_pool, v_pool = core(params, tokens, positions, tables,
                                          lengths, page_ids, offs,
                                          k_pool, v_pool)
            if sampled:
                nxt = sample_tokens(logits, jax.random.fold_in(key, sample_step),
                                    temps, top_ks)
            else:
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return nxt, k_pool, v_pool

        self._decode_fns[key] = step
        return step

    def _horizon_fn(self, bucket: int, pages: int, steps: int,
                    sampled: bool = False):
        """Jitted K-step decode horizon: ``jax.lax.scan`` over ``steps``
        consecutive decode iterations of the SAME per-step core as
        ``_decode_fn``, with the sampled token fed back on-device —
        positions/lengths advance inside the scan, both KV pools ride the
        donated carry, and the host sees only the stacked (K, B) token
        block. Rows whose ``active_steps`` budget is exhausted (request hit
        ``max_new_tokens`` mid-horizon, or bucket padding) are masked: their
        KV writes are redirected to the reserved trash page 0, their
        position freezes, and their carried token repeats — they can never
        corrupt live state or emit extra tokens."""
        fkey = (bucket, pages, steps, sampled)
        if fkey in self._horizon_fns:
            return self._horizon_fns[fkey]
        core = self._decode_core()
        page_size = self.cache.page_size

        @functools.partial(jax.jit, donate_argnums=(4, 5))
        def horizon(params, tokens, positions, tables, k_pool, v_pool,
                    active_steps, key, first_step, temps, top_ks):
            def step_body(carry, t):
                tokens, positions, kpool, vpool = carry
                active = t < active_steps
                lengths = positions + 1
                page_ids = jnp.take_along_axis(
                    tables, (positions // page_size)[:, None], axis=1)[:, 0]
                page_ids = jnp.where(active, page_ids, 0)
                offs = positions % page_size
                logits, kpool, vpool = core(params, tokens, positions, tables,
                                            lengths, page_ids, offs,
                                            kpool, vpool)
                if sampled:
                    nxt = sample_tokens(
                        logits, jax.random.fold_in(key, first_step + t),
                        temps, top_ks)
                else:
                    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                nxt = jnp.where(active, nxt, tokens)
                positions = jnp.where(active, positions + 1, positions)
                return (nxt, positions, kpool, vpool), nxt

            (tokens, positions, k_pool, v_pool), toks = jax.lax.scan(
                step_body, (tokens, positions, k_pool, v_pool),
                jnp.arange(steps, dtype=jnp.int32))
            return toks, k_pool, v_pool

        self._horizon_fns[fkey] = horizon
        return horizon

    def decode_step(self, rids: list[int]) -> dict[int, int]:
        """One continuous-batching decode iteration for the given requests;
        batches larger than the biggest bucket run as multiple bucket-sized
        chunks (no request is ever silently dropped). Returns rid -> new
        token for every rid passed."""
        self._check_alive()
        if not rids:
            return {}
        out: dict[int, int] = {}
        max_bucket = self.decode_buckets[-1]
        for i in range(0, len(rids), max_bucket):
            out.update(self._decode_chunk(rids[i: i + max_bucket]))
        return out

    def _decode_args(self, rids: list[int], claim_ahead: list[int] | None = None):
        """Build the padded device args of a decode batch (shared by the
        plain decode step, the fused mixed step, and the K-step horizon).

        ``claim_ahead`` (per-rid step counts) grows each block table to
        cover the horizon's writes at positions
        ``[context_len - 1, context_len - 1 + a)`` BEFORE the dispatch —
        the page claim-ahead; ``None`` is the single-step claim."""
        B = len(rids)
        bucket = self._bucket(B)
        for i, r in enumerate(rids):
            req = self.requests[r]
            self.cache.ensure(r, req.context_len if claim_ahead is None
                              else req.context_len - 1 + claim_ahead[i])
        pages = self.pad_pages(max(len(self.cache.tables[r]) for r in rids))
        tables = self.cache.batch_tables(rids, pad_to=pages)
        # the input token is the last one in the buffer; its position is
        # context_len - 1 and the cache covers [0, context_len) after writing
        positions = np.array([self.requests[r].context_len - 1 for r in rids], np.int32)
        tokens = np.array([self.token_buf[r][int(pos)] for r, pos in zip(rids, positions)],
                          np.int32)
        lengths = positions + 1
        pad = bucket - B
        if pad:
            tables = np.pad(tables, ((0, pad), (0, 0)))
            positions = np.pad(positions, (0, pad))
            tokens = np.pad(tokens, (0, pad))
            lengths = np.pad(lengths, (0, pad), constant_values=1)
        return bucket, pages, tokens, positions, tables, lengths

    def _decode_finish(self, rids: list[int], nxt: np.ndarray, dt: float) -> dict[int, int]:
        """Per-request bookkeeping after a decode (or fused) dispatch."""
        out = {}
        for i, r in enumerate(rids):
            req = self.requests[r]
            tok = int(nxt[i])
            self.token_buf[r].append(tok)
            req.generated += 1
            req.decode_time_sum += dt
            out[r] = tok
            if req.done:
                req.phase = Phase.FINISHED
                self.cache.free(r)
                self.req_sampling.pop(r, None)
        self.stats.decode_tokens += len(rids)
        self.stats.decode_steps += 1
        self.stats.decode_seconds += dt
        return out

    def _decode_chunk(self, rids: list[int]) -> dict[int, int]:
        t0 = time.perf_counter()
        bucket, pages, tokens, positions, tables, lengths = self._decode_args(rids)
        temps, topks = self._sampling_arrays(rids, bucket)
        sampled = (self.sampling.temperature > 0
                   or any(r in self.req_sampling for r in rids))
        fn = self._decode_fn(bucket, pages, sampled)
        key, sample_step = self._next_key()
        nxt_dev, self.cache.k_pool, self.cache.v_pool = fn(
            self.params, jnp.asarray(tokens), jnp.asarray(positions),
            jnp.asarray(tables), jnp.asarray(lengths),
            self.cache.k_pool, self.cache.v_pool,
            key, sample_step, jnp.asarray(temps), jnp.asarray(topks))
        nxt = np.asarray(nxt_dev)   # (bucket,) ids — the only device->host sync
        self.stats.host_syncs += 1
        self.stats.dispatches_by_kind["decode"] += 1
        return self._decode_finish(rids, nxt, time.perf_counter() - t0)

    # ------------------------------------------------------------------
    # multi-step decode horizons (K fused iterations, one host sync)
    # ------------------------------------------------------------------
    def max_horizon_for(self, rids: list[int], steps: int) -> int:
        """Largest horizon <= ``steps`` whose page claim-ahead fits the free
        pool (the claim is monotone in steps, and the K=1 claim is exactly
        what ``decode_step`` would take, so an admitted batch always gets at
        least 1)."""
        free = self.cache.available_pages

        def need(k: int) -> int:
            tot = 0
            for r in rids:
                req = self.requests[r]
                a = min(k, max(req.remaining, 1))
                tot += max(0, self.cache.pages_for(req.context_len - 1 + a)
                           - len(self.cache.tables.get(r, ())))
            return tot

        while steps > 1 and need(steps) > free:
            steps -= 1
        return max(steps, 1)

    def decode_horizon(self, rids: list[int], steps: int) -> dict[int, list[int]]:
        """Run up to ``steps`` consecutive decode iterations for ``rids`` as
        ONE jitted dispatch: the sampled token of step t feeds step t+1
        on-device, so only a (steps, B) token block crosses the device
        boundary — one host sync per horizon instead of one per token.
        Token-identical to ``steps`` serial ``decode_step`` calls (greedy
        and seeded sampling) for a fixed batch. Requests reaching
        ``max_new_tokens`` mid-horizon stop emitting (masked rows). Batches
        larger than the biggest bucket run as multiple bucket-sized
        horizons. Returns rid -> list of new tokens."""
        self._check_alive()
        if not rids:
            return {}
        steps = int(steps)
        if steps <= 1:
            return {r: [t] for r, t in self.decode_step(rids).items()}
        out: dict[int, list[int]] = {}
        max_bucket = self.decode_buckets[-1]
        for i in range(0, len(rids), max_bucket):
            out.update(self._horizon_chunk(rids[i: i + max_bucket], steps))
        return out

    def _horizon_chunk(self, rids: list[int], steps: int) -> dict[int, list[int]]:
        t0 = time.perf_counter()
        ahead = [min(steps, self.requests[r].remaining) for r in rids]
        assert min(ahead) >= 1, "request already finished"
        bucket, pages, tokens, positions, tables, _ = self._decode_args(
            rids, claim_ahead=ahead)
        active = np.zeros(bucket, np.int32)
        active[: len(rids)] = ahead
        temps, topks = self._sampling_arrays(rids, bucket)
        sampled = (self.sampling.temperature > 0
                   or any(r in self.req_sampling for r in rids))
        fn = self._horizon_fn(bucket, pages, steps, sampled)
        key, first_step = self._next_key_block(steps)
        toks_dev, self.cache.k_pool, self.cache.v_pool = fn(
            self.params, jnp.asarray(tokens), jnp.asarray(positions),
            jnp.asarray(tables), self.cache.k_pool, self.cache.v_pool,
            jnp.asarray(active), key, first_step,
            jnp.asarray(temps), jnp.asarray(topks))
        nxt = np.asarray(toks_dev)  # (steps, bucket) — the ONLY host sync
        dt = time.perf_counter() - t0
        out: dict[int, list[int]] = {}
        total = 0
        for i, r in enumerate(rids):
            req = self.requests[r]
            a = int(active[i])
            toks = [int(x) for x in nxt[:a, i]]
            buf = self.token_buf[r]
            for tok in toks:
                buf.append(tok)
            req.generated += a
            # the horizon's wall time amortizes over its steps; a row that
            # exits early is only charged for the steps it ran
            req.decode_time_sum += dt * a / steps
            total += a
            out[r] = toks
            if req.done:
                req.phase = Phase.FINISHED
                self.cache.free(r)
                self.req_sampling.pop(r, None)
        self.stats.decode_tokens += total
        self.stats.decode_steps += steps
        self.stats.horizon_steps += steps
        self.stats.host_syncs += 1
        self.stats.dispatches_by_kind["horizon"] += 1
        self.stats.decode_seconds += dt
        return out

    # ------------------------------------------------------------------
    # fused mixed prefill/decode step (chunked prefill)
    # ------------------------------------------------------------------
    def _mixed_core(self, dec_bucket: int, chunk_bucket: int,
                    chunk_pages: int):
        """One fused mixed iteration over the layer stack — the computation
        SHARED by the single-step jitted mixed step and the K-step
        mixed-horizon scan, so the two paths are token-identical by
        construction (the same way ``_decode_core`` backs both
        ``decode_step`` and ``decode_horizon``). Returns
        ``core(params, d_tokens, d_positions, d_tables, d_lengths, d_page,
        d_off, c_tokens, c_start, c_len, c_tables, k_pool, v_pool) ->
        (logits, k_pool, v_pool)`` where ``logits`` stacks the decode rows
        (dec_bucket, V) followed by the chunk's last-position row (1, V)."""
        cfg = self.cfg
        model = self.model
        page_size = self.cache.page_size
        use_ref, interpret = backend_flags(self.backend)
        with_decode = dec_bucket > 0
        hd = cfg.head_dim_

        def core(params, d_tokens, d_positions, d_tables, d_lengths,
                 d_page, d_off, c_tokens, c_start, c_len, c_tables,
                 k_pool, v_pool):
            xc = model._embed(params, c_tokens[None])            # (1, C, d)
            c_pos = c_start + jnp.arange(chunk_bucket, dtype=jnp.int32)
            in_chunk = jnp.arange(chunk_bucket) < c_len
            # padded chunk rows scatter into the reserved trash page 0
            # (exactly like padded decode rows) so they can never collide
            # with a real slot of the request's table
            c_page = jnp.where(
                in_chunk,
                c_tables[jnp.minimum(c_pos // page_size, chunk_pages - 1)],
                0)
            c_off = c_pos % page_size
            c_kv_len = (c_start + c_len)[None]                   # (1,)
            if with_decode:
                xd = model._embed(params, d_tokens[:, None])
            else:
                xd = jnp.zeros((), jnp.float32)  # carry placeholder

            def body(carry, inp):
                xd, xc, kpool, vpool = carry
                lp, li = inp
                # ---- KV writes land before either side's gather ----
                if with_decode:
                    hdn = _norm(cfg, lp["ln1"], xd)
                    k_new, v_new = attention.project_kv_for_cache(
                        lp["attn"], hdn, d_positions, cfg)
                    kpool = kpool.at[li, d_page, d_off].set(
                        k_new[:, 0].astype(cfg.jnp_dtype).astype(kpool.dtype))
                    vpool = vpool.at[li, d_page, d_off].set(
                        v_new[:, 0].astype(cfg.jnp_dtype).astype(vpool.dtype))
                hc = _norm(cfg, lp["ln1"], xc)
                qc, kc, vc = attention._project_qkv(
                    lp["attn"], hc, cfg, c_pos[None],
                    rope=not cfg.is_encoder_decoder)
                kpool = kpool.at[li, c_page, c_off].set(
                    kc[0].astype(cfg.jnp_dtype).astype(kpool.dtype))
                vpool = vpool.at[li, c_page, c_off].set(
                    vc[0].astype(cfg.jnp_dtype).astype(vpool.dtype))
                # ---- decode attention: backend paged kernel ----
                if with_decode:
                    q = layers.dense(lp["attn"]["wq"], hdn[:, 0]).reshape(
                        -1, cfg.num_heads, hd)
                    if cfg.qk_norm:
                        q = layers.rmsnorm(lp["attn"]["q_norm"], q, cfg.norm_eps)
                    q = layers.apply_rope(q[:, None], d_positions[:, None],
                                          cfg.rope_theta)[:, 0]
                    B, P = d_tables.shape
                    page = kpool.shape[2]
                    comp_k = kpool[li, d_tables].reshape(B * P, page, *kpool.shape[3:])
                    comp_v = vpool[li, d_tables].reshape(B * P, page, *vpool.shape[3:])
                    local = jnp.arange(B * P, dtype=jnp.int32).reshape(B, P)
                    a = paged_attention(q, comp_k, comp_v, local, d_lengths,
                                        num_kv_heads=cfg.num_kv_heads,
                                        logit_softcap=cfg.attn_logit_softcap,
                                        use_ref=use_ref, interpret=interpret)
                    a = layers.dense(lp["attn"]["wo"], a.reshape(a.shape[0], 1, -1))
                    if cfg.use_post_norm:
                        a = _norm(cfg, lp["post_ln1"], a)
                    xd = xd + a
                    h2 = _norm(cfg, lp["ln2"], xd)
                    if cfg.is_moe:
                        m, _ = moe_lib.moe_mlp(lp["moe"], h2, cfg, groups=1)
                    else:
                        m = layers.mlp(lp["mlp"], h2, cfg.mlp_act)
                    if cfg.use_post_norm:
                        m = _norm(cfg, lp["post_ln2"], m)
                    xd = xd + m
                # ---- chunk attention over the request's landed pages ----
                ck = kpool[li, c_tables].reshape(
                    1, chunk_pages * page_size, *kpool.shape[3:])
                cv = vpool[li, c_tables].reshape(
                    1, chunk_pages * page_size, *vpool.shape[3:])
                ac = attention.flash_attention_xla(
                    qc, ck, cv, causal=True, q_offset=c_start,
                    kv_lens=c_kv_len, logit_softcap=cfg.attn_logit_softcap)
                ac = layers.dense(lp["attn"]["wo"],
                                  ac.reshape(1, chunk_bucket, -1))
                if cfg.use_post_norm:
                    ac = _norm(cfg, lp["post_ln1"], ac)
                xc = xc + ac
                hc2 = _norm(cfg, lp["ln2"], xc)
                if cfg.is_moe:
                    mc, _ = moe_lib.moe_mlp(lp["moe"], hc2, cfg, groups=1)
                else:
                    mc = layers.mlp(lp["mlp"], hc2, cfg.mlp_act)
                if cfg.use_post_norm:
                    mc = _norm(cfg, lp["post_ln2"], mc)
                return (xd, xc + mc, kpool, vpool), None

            (xd, xc, k_pool, v_pool), _ = jax.lax.scan(
                body, (xd, xc, k_pool, v_pool),
                (params["layers"], jnp.arange(cfg.num_layers)))
            # chunk next-token logits from the last REAL chunk position —
            # only meaningful (and only consumed) on the final chunk
            xlast = jax.lax.dynamic_slice_in_dim(
                xc, jnp.maximum(c_len - 1, 0), 1, axis=1)[:, 0]
            logits_c = model._logits(params, xlast)              # (1, V)
            if with_decode:
                logits = jnp.concatenate(
                    [model._logits(params, xd[:, 0]), logits_c], axis=0)
            else:
                logits = logits_c
            return logits, k_pool, v_pool

        return core

    def _mixed_fn(self, dec_bucket: int, dec_pages: int, chunk_bucket: int,
                  chunk_pages: int, sampled: bool = False):
        """Jitted fused step: one dispatch advances a token-budgeted prefill
        chunk AND decodes the resident batch, both writing the same donated
        KV pools. ``dec_bucket == 0`` specializes to a chunk-only step.

        The chunk is a length-bucketed query block at positions
        ``[start, start + c_len)``; its K/V is scattered into the paged pool
        first, then the chunk attends over the request's (gathered) pages —
        i.e. over everything already landed plus itself — with causal
        ``q_offset`` masking and a per-row ``kv_lens`` bound, so one trace
        serves every (chunk length, context) in the bucket."""
        fkey = (dec_bucket, dec_pages, chunk_bucket, chunk_pages, sampled)
        if fkey in self._mixed_fns:
            return self._mixed_fns[fkey]
        core = self._mixed_core(dec_bucket, chunk_bucket, chunk_pages)
        page_size = self.cache.page_size
        with_decode = dec_bucket > 0

        @functools.partial(jax.jit, donate_argnums=(8, 9))
        def step(params, d_tokens, d_positions, d_tables, d_lengths,
                 c_tokens, c_meta, c_tables, k_pool, v_pool,
                 key, sample_step, temps, top_ks):
            # c_meta (2,) int32 = [start (tokens already landed), c_len]
            if with_decode:
                d_page = jnp.take_along_axis(
                    d_tables, (d_positions // page_size)[:, None], axis=1)[:, 0]
                d_off = d_positions % page_size
            else:
                d_page = d_off = jnp.zeros(0, jnp.int32)
            logits, k_pool, v_pool = core(
                params, d_tokens, d_positions, d_tables, d_lengths,
                d_page, d_off, c_tokens, c_meta[0], c_meta[1], c_tables,
                k_pool, v_pool)
            if sampled:
                nxt = sample_tokens(logits, jax.random.fold_in(key, sample_step),
                                    temps, top_ks)
            else:
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return nxt, k_pool, v_pool

        self._mixed_fns[fkey] = step
        return step

    def _mixed_horizon_fn(self, dec_bucket: int, dec_pages: int,
                          chunk_bucket: int, chunk_pages: int, steps: int,
                          sampled: bool = False):
        """Jitted K-step fused mixed horizon: ``jax.lax.scan`` over
        ``steps`` iterations of the SAME per-step core as ``_mixed_fn`` —
        each iteration lands one sub-chunk slice of the pending prefill
        chunk (``c_tokens``/``c_meta`` carry a per-iteration (steps, C)
        token block and (steps, 2) [start, len] metadata as scan xs) while
        running one decode iteration for the resident batch with the
        sampled token fed back on-device. Decode rows whose
        ``active_steps`` budget is exhausted (request hit
        ``max_new_tokens`` mid-horizon, or bucket padding) are masked
        exactly like ``_horizon_fn``: KV writes redirect to the reserved
        trash page 0, positions freeze, carried tokens repeat. Both KV
        pools ride the donated scan carry; the host sees only the stacked
        (steps, dec_bucket + 1) token block — one sync per horizon."""
        fkey = (dec_bucket, dec_pages, chunk_bucket, chunk_pages, steps,
                sampled)
        if fkey in self._mixed_horizon_fns:
            return self._mixed_horizon_fns[fkey]
        core = self._mixed_core(dec_bucket, chunk_bucket, chunk_pages)
        page_size = self.cache.page_size
        with_decode = dec_bucket > 0

        @functools.partial(jax.jit, donate_argnums=(4, 5))
        def horizon(params, d_tokens, d_positions, d_tables, k_pool, v_pool,
                    active_steps, c_tokens, c_meta, c_tables, key,
                    first_step, temps, top_ks):
            def step_body(carry, inp):
                d_toks, d_pos, kpool, vpool = carry
                t, c_tok, c_m = inp
                active = t < active_steps
                d_lengths = d_pos + 1
                if with_decode:
                    d_page = jnp.take_along_axis(
                        d_tables, (d_pos // page_size)[:, None], axis=1)[:, 0]
                    d_page = jnp.where(active, d_page, 0)
                    d_off = d_pos % page_size
                else:
                    d_page = d_off = jnp.zeros(0, jnp.int32)
                logits, kpool, vpool = core(
                    params, d_toks, d_pos, d_tables, d_lengths,
                    d_page, d_off, c_tok, c_m[0], c_m[1], c_tables,
                    kpool, vpool)
                if sampled:
                    nxt = sample_tokens(
                        logits, jax.random.fold_in(key, first_step + t),
                        temps, top_ks)
                else:
                    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                if with_decode:
                    d_toks = jnp.where(active, nxt[:dec_bucket], d_toks)
                    d_pos = jnp.where(active, d_pos + 1, d_pos)
                return (d_toks, d_pos, kpool, vpool), nxt

            (d_tokens, d_positions, k_pool, v_pool), toks = jax.lax.scan(
                step_body, (d_tokens, d_positions, k_pool, v_pool),
                (jnp.arange(steps, dtype=jnp.int32), c_tokens, c_meta))
            return toks, k_pool, v_pool

        self._mixed_horizon_fns[fkey] = horizon
        return horizon

    def mixed_step(self, decode_rids: list[int], prefill_rid: int | None = None,
                   chunk_tokens: int = 0) -> dict[int, int]:
        """One co-located iteration: decode ``decode_rids`` while advancing
        ``prefill_rid``'s chunk-granular prefill by up to ``chunk_tokens``
        prompt tokens, fused into a single dispatch when both sides are
        present. Either side may be empty (falls back to plain decode /
        chunk-only prefill). Returns rid -> new token for the decode rids;
        chunk progress is visible via ``prefill_progress`` and the request's
        phase flip to DECODING once the prompt completes."""
        self._check_alive()
        if prefill_rid is None or chunk_tokens <= 0:
            return self.decode_step(decode_rids)
        max_bucket = self.decode_buckets[-1]
        first = decode_rids[:max_bucket]
        out = self._mixed_dispatch(first, prefill_rid, chunk_tokens)
        for i in range(max_bucket, len(decode_rids), max_bucket):
            out.update(self._decode_chunk(decode_rids[i: i + max_bucket]))
        return out

    def prefill_progress(self, rid: int) -> int:
        """Prompt tokens landed so far by the chunked path (0 if none)."""
        state = self.chunk_state.get(rid)
        return state.done if state is not None else 0

    def claim_prefix(self, rid: int) -> int:
        """Match the request's prompt against the radix prefix cache and
        claim the hit by bumping page refcounts. Returns the matched token
        count (0 on miss / cache disabled). The match is capped at
        ``prompt_len - 1`` and rounded down to a page boundary, so the
        uncached suffix is >= 1 token and starts exactly on a fresh page:
        shared pages are never written — copy-on-write by construction.
        Chunked prefill then resumes at the match boundary."""
        if self.cache.prefix is None:
            return 0
        if rid in self.chunk_state or rid in self.cache.tables:
            return 0   # already started (warm or cold) — nothing to claim
        req = self.requests[rid]
        tokens = np.asarray(self.token_buf[rid][: req.prompt_len], np.int32)
        pages, matched = self.cache.prefix.match(
            tokens.tolist(), limit=req.prompt_len - 1)
        if matched == 0:
            return 0
        self.cache.adopt(rid, pages, matched)
        self.chunk_state[rid] = ChunkedPrefill(
            rid, tokens, done=matched, cached=matched)
        req.prefill_tokens_done = matched
        req.cached_tokens = matched
        self.stats.prefix_hits += 1
        self.stats.cached_tokens += matched
        self.stats.shared_pages += len(pages)
        return matched

    def _mixed_dispatch(self, rids: list[int], prid: int,
                        chunk_tokens: int) -> dict[int, int]:
        t0 = time.perf_counter()
        req = self.requests[prid]
        state = self.chunk_state.get(prid)
        if state is None:
            assert prid not in self.partial, \
                "request already mid layer-granular prefill"
            # direct engine users reach the cache here; the cluster runtime
            # claims earlier (at admission) so planning sees residual work
            self.claim_prefix(prid)
            state = self.chunk_state.get(prid)
        if state is None:
            state = self.chunk_state[prid] = ChunkedPrefill(
                prid, np.asarray(self.token_buf[prid][: req.prompt_len],
                                 np.int32))
        c = min(int(chunk_tokens), req.prompt_len - state.done)
        assert c >= 1, "prefill already complete"
        req.phase = Phase.PREFILLING
        # pages are claimed chunk-by-chunk, so a preempted prefill only ever
        # holds capacity for what it has actually landed
        self.cache.ensure(prid, state.done + c)
        C = self.pad_chunk(c)
        c_tok = np.zeros(C, np.int32)
        c_tok[:c] = state.tokens[state.done: state.done + c]
        table = self.cache.tables[prid]
        cp = self.pad_pages(len(table))
        c_tables = np.zeros(cp, np.int32)
        c_tables[: len(table)] = table
        c_meta = np.array([state.done, c], np.int32)
        if rids:
            bucket, pages, tokens, positions, tables, lengths = \
                self._decode_args(rids)
        else:
            bucket, pages = 0, 0
            tokens = positions = lengths = np.zeros(0, np.int32)
            tables = np.zeros((0, 0), np.int32)
        temps, topks = self._sampling_arrays(rids, bucket + 1)
        d = (self.sampling.temperature, self.sampling.top_k)
        temps[bucket], topks[bucket] = self.req_sampling.get(prid, d)
        sampled = (self.sampling.temperature > 0
                   or any(r in self.req_sampling for r in [*rids, prid]))
        fn = self._mixed_fn(bucket, pages, C, cp, sampled)
        key, sample_step = self._next_key()
        nxt_dev, self.cache.k_pool, self.cache.v_pool = fn(
            self.params, jnp.asarray(tokens), jnp.asarray(positions),
            jnp.asarray(tables), jnp.asarray(lengths),
            jnp.asarray(c_tok), jnp.asarray(c_meta), jnp.asarray(c_tables),
            self.cache.k_pool, self.cache.v_pool,
            key, sample_step, jnp.asarray(temps), jnp.asarray(topks))
        nxt = np.asarray(nxt_dev)   # (bucket + 1,) — single host sync
        self.stats.host_syncs += 1
        dt = time.perf_counter() - t0
        out = self._decode_finish(rids, nxt, dt) if rids else {}
        state.done += c
        req.prefill_tokens_done = state.done
        self.stats.prefill_chunks += 1
        self.stats.dispatches_by_kind["mixed" if rids else "prefill"] += 1
        if rids:
            self.stats.mixed_steps += 1
        else:
            self.stats.prefill_seconds += dt
        if state.done >= req.prompt_len:
            self.token_buf[prid].append(int(nxt[-1]))
            req.generated = 1
            req.phase = Phase.DECODING
            self.stats.prefill_tokens += req.prompt_len
            if self.cache.prefix is not None:
                # publish the full pages into the radix tree (refcount bump
                # per adopted page) so later prompts can reuse them; the
                # partial tail page stays private
                self.cache.prefix.insert(
                    state.tokens.tolist(), self.cache.tables[prid])
            del self.chunk_state[prid]
            if req.done:   # one-output request: finished at prefill
                req.phase = Phase.FINISHED
                self.cache.free(prid)
                self.req_sampling.pop(prid, None)
        return out

    # ------------------------------------------------------------------
    # fused mixed-horizon dispatch (chunk + K decode iterations, one sync)
    # ------------------------------------------------------------------
    def max_mixed_horizon_for(self, rids: list[int], prid: int,
                              chunk_tokens: int, steps: int) -> int:
        """Largest horizon <= ``steps`` whose combined page claim-ahead —
        the FULL chunk for ``prid`` plus up to ``steps`` decode tokens per
        resident — fits the free pool. The chunk's claim is set aside
        first (it does not shrink with K: the whole chunk lands inside one
        horizon either way), then K shrinks like ``max_horizon_for``
        against the remainder, so neither side can starve the other into
        ``OutOfPagesError`` mid-scan."""
        req = self.requests[prid]
        done = req.prefill_tokens_done
        c = min(int(chunk_tokens), req.prompt_len - done)
        chunk_need = max(0, self.cache.pages_for(done + max(c, 1))
                         - len(self.cache.tables.get(prid, ())))
        free = self.cache.available_pages - chunk_need

        def need(k: int) -> int:
            tot = 0
            for r in rids:
                rq = self.requests[r]
                a = min(k, max(rq.remaining, 1))
                tot += max(0, self.cache.pages_for(rq.context_len - 1 + a)
                           - len(self.cache.tables.get(r, ())))
            return tot

        steps = min(int(steps), max(c, 1))
        while steps > 1 and need(steps) > free:
            steps -= 1
        return max(steps, 1)

    def mixed_horizon(self, decode_rids: list[int],
                      prefill_rid: int | None = None, chunk_tokens: int = 0,
                      steps: int = 1) -> dict[int, list[int]]:
        """Run up to ``steps`` fused mixed iterations as ONE jitted
        dispatch: per iteration a ``chunk_tokens / steps`` slice of
        ``prefill_rid``'s pending chunk lands in the donated KV pools while
        one decode iteration runs for ``decode_rids`` with on-device token
        feedback — K steps, one host sync. Token-identical to ``steps``
        serial ``mixed_step`` calls (greedy and seeded sampling for a
        fixed batch; rows hitting ``max_new_tokens`` mid-horizon stop
        emitting via masking). Falls back to ``decode_horizon`` when no
        chunk rides and to ``mixed_step`` when ``steps <= 1``. Decode rids
        beyond the biggest bucket run as plain decode horizons alongside.
        Returns rid -> list of new tokens for the decode rids; chunk
        progress is visible via ``prefill_progress`` and the phase flip to
        DECODING once the prompt completes."""
        self._check_alive()
        if prefill_rid is None or chunk_tokens <= 0:
            return self.decode_horizon(decode_rids, steps)
        steps = int(steps)
        if steps <= 1:
            return {r: [t] for r, t in self.mixed_step(
                decode_rids, prefill_rid, chunk_tokens).items()}
        max_bucket = self.decode_buckets[-1]
        out = self._mixed_horizon_dispatch(
            decode_rids[:max_bucket], prefill_rid, chunk_tokens, steps)
        rest = decode_rids[max_bucket:]
        if rest:
            out.update(self.decode_horizon(rest, steps))
        return out

    def _mixed_horizon_dispatch(self, rids: list[int], prid: int,
                                chunk_tokens: int,
                                steps: int) -> dict[int, list[int]]:
        t0 = time.perf_counter()
        req = self.requests[prid]
        state = self.chunk_state.get(prid)
        if state is None:
            assert prid not in self.partial, \
                "request already mid layer-granular prefill"
            self.claim_prefix(prid)
            state = self.chunk_state.get(prid)
        if state is None:
            state = self.chunk_state[prid] = ChunkedPrefill(
                prid, np.asarray(self.token_buf[prid][: req.prompt_len],
                                 np.int32))
        c = min(int(chunk_tokens), req.prompt_len - state.done)
        assert c >= 1, "prefill already complete"
        steps = min(steps, c)   # every sub-chunk must carry >= 1 token
        if steps <= 1:
            return {r: [t] for r, t in
                    self._mixed_dispatch(rids, prid, c).items()}
        req.phase = Phase.PREFILLING
        # the WHOLE horizon's chunk is claimed up front (claim-ahead to the
        # horizon end); sub-chunks land into it iteration by iteration
        self.cache.ensure(prid, state.done + c)
        subs = split_chunk(c, steps)
        C = self.pad_chunk(max(subs))
        c_toks = np.zeros((steps, C), np.int32)
        c_meta = np.zeros((steps, 2), np.int32)
        pos = state.done
        for i, s in enumerate(subs):
            c_toks[i, :s] = state.tokens[pos: pos + s]
            c_meta[i] = (pos, s)
            pos += s
        table = self.cache.tables[prid]
        cp = self.pad_pages(len(table))
        c_tables = np.zeros(cp, np.int32)
        c_tables[: len(table)] = table
        if rids:
            ahead = [min(steps, self.requests[r].remaining) for r in rids]
            assert min(ahead) >= 1, "request already finished"
            bucket, pages, tokens, positions, tables, _ = self._decode_args(
                rids, claim_ahead=ahead)
        else:
            ahead = []
            bucket, pages = 0, 0
            tokens = positions = np.zeros(0, np.int32)
            tables = np.zeros((0, 0), np.int32)
        active = np.zeros(bucket, np.int32)
        active[: len(rids)] = ahead
        temps, topks = self._sampling_arrays(rids, bucket + 1)
        d = (self.sampling.temperature, self.sampling.top_k)
        temps[bucket], topks[bucket] = self.req_sampling.get(prid, d)
        sampled = (self.sampling.temperature > 0
                   or any(r in self.req_sampling for r in [*rids, prid]))
        fn = self._mixed_horizon_fn(bucket, pages, C, cp, steps, sampled)
        key, first_step = self._next_key_block(steps)
        toks_dev, self.cache.k_pool, self.cache.v_pool = fn(
            self.params, jnp.asarray(tokens), jnp.asarray(positions),
            jnp.asarray(tables), self.cache.k_pool, self.cache.v_pool,
            jnp.asarray(active), jnp.asarray(c_toks), jnp.asarray(c_meta),
            jnp.asarray(c_tables), key, first_step,
            jnp.asarray(temps), jnp.asarray(topks))
        nxt = np.asarray(toks_dev)  # (steps, bucket + 1) — the ONLY sync
        self.stats.host_syncs += 1
        self.stats.dispatches_by_kind["mixed_horizon"] += 1
        dt = time.perf_counter() - t0
        out: dict[int, list[int]] = {}
        total = 0
        for i, r in enumerate(rids):
            rq = self.requests[r]
            a = int(active[i])
            toks = [int(x) for x in nxt[:a, i]]
            buf = self.token_buf[r]
            for tok in toks:
                buf.append(tok)
            rq.generated += a
            # the horizon's wall time amortizes over its steps; a row that
            # exits early is only charged for the steps it ran
            rq.decode_time_sum += dt * a / steps
            total += a
            out[r] = toks
            if rq.done:
                rq.phase = Phase.FINISHED
                self.cache.free(r)
                self.req_sampling.pop(r, None)
        state.done += c
        req.prefill_tokens_done = state.done
        self.stats.prefill_chunks += steps
        if rids:
            self.stats.decode_tokens += total
            self.stats.decode_steps += steps
            self.stats.horizon_steps += steps
            self.stats.mixed_steps += steps
            self.stats.decode_seconds += dt
        else:
            self.stats.prefill_seconds += dt
        if state.done >= req.prompt_len:
            # sub-chunks all carry >= 1 token and sum to c, so the prompt
            # can only complete at the FINAL iteration — its chunk-row
            # sample is the first generated token
            self.token_buf[prid].append(int(nxt[-1, bucket]))
            req.generated = 1
            req.phase = Phase.DECODING
            self.stats.prefill_tokens += req.prompt_len
            if self.cache.prefix is not None:
                self.cache.prefix.insert(
                    state.tokens.tolist(), self.cache.tables[prid])
            del self.chunk_state[prid]
            if req.done:   # one-output request: finished at prefill
                req.phase = Phase.FINISHED
                self.cache.free(prid)
                self.req_sampling.pop(prid, None)
        return out

    # ------------------------------------------------------------------
    def evict(self, rid: int) -> None:
        """Evict a decoding request (offline victim): free pages; it must
        re-prefill (recompute) later. Prefix-cache claims were a page-table
        update, not compute — like ``abort_prefill``/``_readmit``, losing
        them wastes no FLOPs, so only context beyond the claimed prefix
        counts as recompute."""
        req = self.requests[rid]
        req.recompute_tokens += max(req.context_len - req.cached_tokens, 0)
        req.evictions += 1
        req.phase = Phase.EVICTED
        self.cache.free(rid)
        self.stats.evictions += 1

    def release(self, rid: int) -> None:
        """Drop EVERY trace of a request from this engine — the cancel
        path. Idempotent and stage-agnostic: safe whether the request is
        mid-chunked-prefill, mid-legacy-prefill, decoding, already finished
        (pages freed by ``_decode_finish``), or unknown here. Unlike
        ``abort_prefill``/``evict`` it bills no recompute waste (a
        cancelled request will never re-run) and never raises on absent
        state, so the runtime can call it on every slot it might have
        touched. No-op on a crashed engine (its state is already gone)."""
        self.partial.pop(rid, None)
        self.chunk_state.pop(rid, None)
        self.req_sampling.pop(rid, None)
        self.requests.pop(rid, None)
        self.token_buf.pop(rid, None)
        if rid in self.cache.tables:
            self.cache.free(rid)
        else:
            self.cache.lengths.pop(rid, None)

    def migrate_out(self, rid: int):
        """Export KV for migration to another engine (RDMA->ICI analogue)."""
        k, v, n = self.cache.export_request(rid)
        self.cache.free(rid)
        return k, v, n

    def export_for_transfer(self, rid: int):
        """Export KV *without* freeing the source pages, plus an integrity
        checksum — the retry-safe transfer primitive: the source keeps its
        state until the destination has verified and imported the payload
        (``commit_transfer_out`` then releases it)."""
        k, v, n = self.cache.export_request(rid)
        return k, v, n, transfer_checksum(k, v)

    def commit_transfer_out(self, rid: int) -> None:
        """Release a request's local state after a verified transfer."""
        self.cache.free(rid)
        self.requests.pop(rid, None)
        self.token_buf.pop(rid, None)

    def migrate_in(self, rid: int, req: Request, tokens, k, v, n: int,
                   sampling: tuple[float, int] | None = None,
                   checksum: float | None = None) -> None:
        self._check_alive()
        if checksum is not None:
            # raises TransferIntegrityError BEFORE any state lands here —
            # a corrupt payload leaves the destination untouched so the
            # source can simply re-send
            verify_transfer(k, v, checksum)
        self.requests[rid] = req
        toks = list(tokens)
        self.token_buf[rid] = TokenRing(
            toks, capacity=len(toks) + max(req.remaining, 0) + 8)
        if sampling is not None:
            self.req_sampling[rid] = sampling
        self.cache.import_request(rid, k, v, n)
        req.phase = Phase.DECODING
