"""Serving engine: continuous batching + paged KV + layer-interruptible prefill.

One ``ServingEngine`` is an xllm-instance analogue (DESIGN §3): it holds the
model weights once and can run Prefill and/or Decode iterations. The paper's
two mechanisms are implemented for real, not simulated:

* **Layer-level interruption** (§3.4.1): prefill executes as a sequence of
  per-layer jitted calls carrying the hidden state; between layers the engine
  polls a preemption callback. An interrupted prefill keeps (hidden, layer
  index, KV-so-far) and resumes exactly where it stopped — tests assert
  bit-compatible logits vs an uninterrupted run.
* **Mix decoding selection** (§3.4.4): each decode iteration builds its batch
  with ``core.scheduling.mix_decoding_selection`` under the TPOT SLO using
  the roofline perf model.

Decode batches are padded to bucket sizes (TPU/XLA static shapes, DESIGN §3).
Supported families here: dense + MoE with a single attention window (the
cluster-scale behaviour of every family is exercised via the simulator).
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.perf_model import PerfModel
from repro.core.request import Kind, Phase, Request
from repro.engine.kv_cache import PagedKVCache
from repro.kernels.paged_attention.ops import paged_attention
from repro.models import attention, layers, moe as moe_lib
from repro.models.config import ModelConfig
from repro.models.transformer import Transformer, _norm


@dataclass
class PartialPrefill:
    """State of a layer-interrupted prefill (resume token)."""
    rid: int
    x: jnp.ndarray            # hidden after `layer` layers, (1, S, d)
    layer: int                # layers completed
    tokens: np.ndarray


@dataclass
class EngineStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0
    preemptions: int = 0
    evictions: int = 0
    decode_steps: int = 0
    prefill_seconds: float = 0.0
    decode_seconds: float = 0.0


class ServingEngine:
    def __init__(self, model: Transformer, params, *, num_pages: int = 512,
                 page_size: int = 16, decode_buckets: tuple[int, ...] = (1, 2, 4, 8, 16, 32),
                 perf_model: PerfModel | None = None):
        cfg = model.cfg
        assert not cfg.local_global and not cfg.sliding_window, \
            "engine supports full-attention archs (cluster-scale behaviour of " \
            "windowed/SSM families is exercised via the simulator)"
        self.model = model
        self.cfg = cfg
        self.params = params
        self.cache = PagedKVCache(cfg, num_pages, page_size)
        self.decode_buckets = tuple(sorted(decode_buckets))
        self.perf_model = perf_model
        self.requests: dict[int, Request] = {}
        self.token_buf: dict[int, list[int]] = {}   # prompt + generated tokens
        self.partial: dict[int, PartialPrefill] = {}
        self.stats = EngineStats()
        self._layer_fn = self._build_layer_fn()
        self._embed_fn = jax.jit(lambda p, t: model._embed(p, t))
        self._logits_fn = jax.jit(lambda p, x: model._logits(p, x))
        self._decode_fns: dict[tuple[int, int], Callable] = {}

    # ------------------------------------------------------------------
    # layer-interruptible prefill
    # ------------------------------------------------------------------
    def _build_layer_fn(self):
        cfg = self.cfg
        model = self.model

        @jax.jit
        def layer_fn(lp, x, positions):
            h = _norm(cfg, lp["ln1"], x)
            a, (k, v) = attention.attn_prefill(
                lp["attn"], h, positions, cfg, window=cfg.sliding_window,
                impl="xla")
            if cfg.use_post_norm:
                a = _norm(cfg, lp["post_ln1"], a)
            x = x + a
            h = _norm(cfg, lp["ln2"], x)
            if cfg.is_moe:
                m, _ = moe_lib.moe_mlp(lp["moe"], h, cfg, groups=1)
            else:
                m = layers.mlp(lp["mlp"], h, cfg.mlp_act)
            if cfg.use_post_norm:
                m = _norm(cfg, lp["post_ln2"], m)
            return x + m, k, v

        return layer_fn

    def _layer_params(self, i: int):
        return jax.tree.map(lambda a: a[i], self.params["layers"])

    def add_request(self, req: Request, prompt_tokens: list[int]) -> None:
        assert len(prompt_tokens) == req.prompt_len
        self.requests[req.rid] = req
        self.token_buf[req.rid] = list(prompt_tokens)

    def prefill(self, rid: int, *, should_preempt: Callable[[], bool] | None = None,
                max_new_pages: bool = True) -> str:
        """Run (or resume) prefill for one request, checking the preemption
        callback between transformer layers. Returns "done" | "preempted"."""
        t0 = time.perf_counter()
        req = self.requests[rid]
        cfg = self.cfg
        if rid in self.partial:
            part = self.partial.pop(rid)
            x, start_layer, tokens = part.x, part.layer, part.tokens
        else:
            tokens = np.asarray(self.token_buf[rid][: req.prompt_len], np.int32)
            self.cache.ensure(rid, req.prompt_len)
            x = self._embed_fn(self.params, jnp.asarray(tokens)[None])
            start_layer = 0
        S = tokens.shape[0]
        positions = jnp.arange(S)[None]
        req.phase = Phase.PREFILLING
        for li in range(start_layer, cfg.num_layers):
            x, k, v = self._layer_fn(self._layer_params(li), x, positions)
            self.cache.write_prefill_layer(rid, li, k[0], v[0])
            req.prefill_layers_done = li + 1
            if should_preempt is not None and li < cfg.num_layers - 1 and should_preempt():
                self.partial[rid] = PartialPrefill(rid, x, li + 1, tokens)
                self.stats.preemptions += 1
                self.stats.prefill_seconds += time.perf_counter() - t0
                return "preempted"
        # first token from the last hidden state
        logits = self._logits_fn(self.params, x[:, -1])
        nxt = int(jnp.argmax(logits, -1)[0])
        self.token_buf[rid].append(nxt)
        req.generated = 1
        req.phase = Phase.DECODING
        self.stats.prefill_tokens += S
        self.stats.prefill_seconds += time.perf_counter() - t0
        return "done"

    def abort_prefill(self, rid: int) -> None:
        """Discard partial prefill (offline request pushed back to queue)."""
        self.partial.pop(rid, None)
        self.cache.free(rid)
        req = self.requests[rid]
        req.recompute_tokens += req.prompt_len
        req.prefill_layers_done = 0
        req.phase = Phase.QUEUED

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def _bucket(self, n: int) -> int:
        for b in self.decode_buckets:
            if n <= b:
                return b
        return self.decode_buckets[-1]

    def _decode_fn(self, bucket: int, pages: int):
        key = (bucket, pages)
        if key in self._decode_fns:
            return self._decode_fns[key]
        cfg = self.cfg
        model = self.model

        @jax.jit
        def step(params, tokens, positions, tables, lengths, k_pool, v_pool):
            x = model._embed(params, tokens[:, None])
            hd = cfg.head_dim_

            def body(x, inp):
                lp, kp, vp = inp
                h = _norm(cfg, lp["ln1"], x)
                k_new, v_new = attention.project_kv_for_cache(lp["attn"], h, positions, cfg)
                page_ids = jnp.take_along_axis(
                    tables, (positions // self.cache.page_size)[:, None], axis=1)[:, 0]
                offs = positions % self.cache.page_size
                kp = kp.at[page_ids, offs].set(k_new[:, 0].astype(kp.dtype))
                vp = vp.at[page_ids, offs].set(v_new[:, 0].astype(vp.dtype))
                q = layers.dense(lp["attn"]["wq"], h[:, 0]).reshape(
                    -1, cfg.num_heads, hd)
                if cfg.qk_norm:
                    q = layers.rmsnorm(lp["attn"]["q_norm"], q, cfg.norm_eps)
                q = layers.apply_rope(q[:, None], positions[:, None], cfg.rope_theta)[:, 0]
                a = paged_attention(q, kp, vp, tables, lengths,
                                    num_kv_heads=cfg.num_kv_heads,
                                    logit_softcap=cfg.attn_logit_softcap,
                                    use_ref=True)
                a = layers.dense(lp["attn"]["wo"], a.reshape(a.shape[0], 1, -1))
                if cfg.use_post_norm:
                    a = _norm(cfg, lp["post_ln1"], a)
                x = x + a
                h = _norm(cfg, lp["ln2"], x)
                if cfg.is_moe:
                    m, _ = moe_lib.moe_mlp(lp["moe"], h, cfg, groups=1)
                else:
                    m = layers.mlp(lp["mlp"], h, cfg.mlp_act)
                if cfg.use_post_norm:
                    m = _norm(cfg, lp["post_ln2"], m)
                return x + m, (kp, vp)

            x, (k_pool, v_pool) = jax.lax.scan(body, x, (params["layers"], k_pool, v_pool))
            logits = model._logits(params, x[:, 0])
            return logits, k_pool, v_pool

        self._decode_fns[key] = step
        return step

    def decode_step(self, rids: list[int]) -> dict[int, int]:
        """One continuous-batching decode iteration for the given requests.
        Returns rid -> new token."""
        if not rids:
            return {}
        t0 = time.perf_counter()
        B = len(rids)
        bucket = self._bucket(B)
        rids = rids[:bucket]
        B = len(rids)
        for r in rids:
            req = self.requests[r]
            self.cache.ensure(r, req.context_len)
        pages = max(len(self.cache.tables[r]) for r in rids)
        # pad the page dimension to a small set of sizes to bound compilations
        pages = 1 << (pages - 1).bit_length()
        tables = self.cache.batch_tables(rids, pad_to=pages)
        # the input token is the last one in the buffer; its position is
        # context_len - 1 and the cache covers [0, context_len) after writing
        positions = np.array([self.requests[r].context_len - 1 for r in rids], np.int32)
        tokens = np.array([self.token_buf[r][pos] for r, pos in zip(rids, positions)],
                          np.int32)
        lengths = positions + 1
        pad = bucket - B
        if pad:
            tables = np.pad(tables, ((0, pad), (0, 0)))
            positions = np.pad(positions, (0, pad))
            tokens = np.pad(tokens, (0, pad))
            lengths = np.pad(lengths, (0, pad), constant_values=1)
        fn = self._decode_fn(bucket, pages)
        logits, self.cache.k_pool, self.cache.v_pool = fn(
            self.params, jnp.asarray(tokens), jnp.asarray(positions),
            jnp.asarray(tables), jnp.asarray(lengths),
            self.cache.k_pool, self.cache.v_pool)
        nxt = np.asarray(jnp.argmax(logits, -1), np.int32)
        out = {}
        dt = time.perf_counter() - t0
        for i, r in enumerate(rids):
            req = self.requests[r]
            tok = int(nxt[i])
            self.token_buf[r].append(tok)
            req.generated += 1
            req.decode_time_sum += dt
            out[r] = tok
            if req.done:
                req.phase = Phase.FINISHED
                self.cache.free(r)
        self.stats.decode_tokens += B
        self.stats.decode_steps += 1
        self.stats.decode_seconds += dt
        return out

    # ------------------------------------------------------------------
    def evict(self, rid: int) -> None:
        """Evict a decoding request (offline victim): free pages; it must
        re-prefill (recompute) later."""
        req = self.requests[rid]
        req.recompute_tokens += req.context_len
        req.evictions += 1
        req.phase = Phase.EVICTED
        self.cache.free(rid)
        self.stats.evictions += 1

    def migrate_out(self, rid: int):
        """Export KV for migration to another engine (RDMA->ICI analogue)."""
        k, v, n = self.cache.export_request(rid)
        self.cache.free(rid)
        return k, v, n

    def migrate_in(self, rid: int, req: Request, tokens: list[int], k, v, n: int) -> None:
        self.requests[rid] = req
        self.token_buf[rid] = list(tokens)
        self.cache.import_request(rid, k, v, n)
        req.phase = Phase.DECODING
