"""Serving engine: continuous batching + paged KV + layer-interruptible prefill.

One ``ServingEngine`` is an xllm-instance analogue (DESIGN §3): it holds the
model weights once and can run Prefill and/or Decode iterations. The paper's
two mechanisms are implemented for real, not simulated:

* **Layer-level interruption** (§3.4.1): prefill executes as a sequence of
  per-layer jitted calls carrying the hidden state; between layers the engine
  polls a preemption callback. An interrupted prefill keeps (hidden, layer
  index, KV-so-far) and resumes exactly where it stopped — tests assert
  bit-compatible logits vs an uninterrupted run.
* **Mix decoding selection** (§3.4.4): each decode iteration builds its batch
  with ``core.scheduling.mix_decoding_selection`` under the TPOT SLO using
  the roofline perf model.

Decode batches are padded to bucket sizes (TPU/XLA static shapes, DESIGN §3).
Supported families here: dense + MoE with a single attention window (the
cluster-scale behaviour of every family is exercised via the simulator).

Engine hot path & attention backends
------------------------------------
The per-iteration hot path is allocation- and sync-free:

* ``backend="auto"|"pallas"|"interpret"|"ref"`` selects the attention
  implementation everywhere (prefill flash + paged decode attention).
  ``auto`` resolves to the Pallas TPU kernels when a TPU is attached and to
  the XLA/jnp reference path on CPU; ``interpret`` runs the Pallas kernel
  bodies on any backend (parity/debug). Threaded through ``CoLocatedServer``
  and ``launch.serve --backend``.
* ``k_pool``/``v_pool`` are **donated** through the jitted decode step and
  through the prefill KV scatter, so XLA writes the paged pools in place
  instead of copying the full (L, num_pages, page, Hkv, hd) arrays every
  iteration. Prefill buffers each layer's K/V and lands the whole prefill
  in a single donated scatter (one more at each preemption point).
* Sampling (greedy, or temperature/top-k via ``SamplingParams`` /
  ``set_sampling``) runs **inside** the jitted decode step — only the (B,)
  next-token ids cross the device boundary, never (B, vocab) logits.
* Per-layer parameters are pre-sliced once at construction; per-step token
  bookkeeping uses preallocated numpy rings (``TokenRing``), not Python
  lists.

``benchmarks/bench_decode_hotpath.py`` measures steps/s and host overhead
per step and verifies pool donation from the lowered HLO;
``BENCH_engine.json`` records the baseline→after throughput trajectory.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.perf_model import PerfModel
from repro.core.request import Phase, Request
from repro.engine.kv_cache import PagedKVCache
from repro.kernels import backend_flags, resolve_backend
from repro.kernels.paged_attention.ops import paged_attention
from repro.models import attention, layers, moe as moe_lib
from repro.models.attention import impl_for_backend
from repro.models.transformer import Transformer, _norm


@dataclass
class SamplingParams:
    """Engine-default sampling. ``temperature <= 0`` means greedy; ``top_k``
    0 keeps the full vocab. Per-request overrides via ``set_sampling``."""
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0


def sample_tokens(logits, key, temps, top_ks):
    """On-device sampler: greedy rows where temps <= 0, temperature/top-k
    elsewhere. logits (B, V) f32; temps (B,) f32; top_ks (B,) int32
    (0 = full vocab). Returns (B,) int32 token ids."""
    V = logits.shape[-1]
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    srt = jnp.sort(logits, axis=-1)[:, ::-1]
    k_eff = jnp.clip(jnp.where(top_ks > 0, top_ks, V), 1, V)
    thresh = jnp.take_along_axis(srt, (k_eff - 1)[:, None], axis=-1)
    masked = jnp.where(logits >= thresh, logits, -jnp.inf)
    scaled = masked / jnp.maximum(temps, 1e-6)[:, None]
    sampled = jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
    return jnp.where(temps > 0, sampled, greedy)


class TokenRing:
    """Preallocated int32 token buffer (prompt + generated) with list-like
    reads. Appends write into preallocated storage (amortized O(1), no
    per-token Python list growth); capacity doubles if exceeded."""

    __slots__ = ("_buf", "_n")

    def __init__(self, tokens, capacity: int = 0):
        tokens = np.asarray(list(tokens), np.int32)
        cap = max(capacity, tokens.shape[0], 8)
        self._buf = np.empty(cap, np.int32)
        self._buf[: tokens.shape[0]] = tokens
        self._n = tokens.shape[0]

    def append(self, tok: int) -> None:
        if self._n == self._buf.shape[0]:
            grown = np.empty(self._buf.shape[0] * 2, np.int32)
            grown[: self._n] = self._buf
            self._buf = grown
        self._buf[self._n] = tok
        self._n += 1

    def tolist(self) -> list[int]:
        return self._buf[: self._n].tolist()

    def __len__(self) -> int:
        return self._n

    def __getitem__(self, i):
        if isinstance(i, slice):
            return self._buf[: self._n][i].tolist()
        n = self._n
        if not -n <= i < n:
            raise IndexError(i)
        return int(self._buf[i % n if i < 0 else i])

    def __iter__(self):
        return iter(self.tolist())

    def __eq__(self, other) -> bool:
        if isinstance(other, TokenRing):
            return self.tolist() == other.tolist()
        if isinstance(other, (list, tuple)):
            return self.tolist() == list(other)
        return NotImplemented

    def __repr__(self) -> str:
        return f"TokenRing({self.tolist()})"


@dataclass
class PartialPrefill:
    """State of a layer-interrupted prefill (resume token). KV of completed
    layers is already flushed to the paged pool (one donated scatter per
    interruption segment)."""
    rid: int
    x: jnp.ndarray            # hidden after `layer` layers, (1, S, d)
    layer: int                # layers completed
    tokens: np.ndarray


@dataclass
class EngineStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0
    preemptions: int = 0
    evictions: int = 0
    decode_steps: int = 0
    prefill_seconds: float = 0.0
    decode_seconds: float = 0.0


class ServingEngine:
    def __init__(self, model: Transformer, params, *, num_pages: int = 512,
                 page_size: int = 16, decode_buckets: tuple[int, ...] = (1, 2, 4, 8, 16, 32),
                 perf_model: PerfModel | None = None, backend: str = "auto",
                 sampling: SamplingParams | None = None,
                 kernels_from: "ServingEngine | None" = None):
        cfg = model.cfg
        assert not cfg.local_global and not cfg.sliding_window, \
            "engine supports full-attention archs (cluster-scale behaviour of " \
            "windowed/SSM families is exercised via the simulator)"
        self.model = model
        self.cfg = cfg
        self.params = params
        self.backend = resolve_backend(backend)
        self.sampling = sampling or SamplingParams()
        self.cache = PagedKVCache(cfg, num_pages, page_size)
        self.decode_buckets = tuple(sorted(decode_buckets))
        self.perf_model = perf_model
        self.requests: dict[int, Request] = {}
        self.token_buf: dict[int, TokenRing] = {}   # prompt + generated tokens
        self.partial: dict[int, PartialPrefill] = {}
        self.req_sampling: dict[int, tuple[float, int]] = {}
        self.stats = EngineStats()
        if kernels_from is not None:
            # Pool runtimes run N+M engines over the SAME weights; the jitted
            # step functions only close over (model, cfg, page_size, backend),
            # so sibling engines can share one compiled-kernel set instead of
            # re-tracing/compiling per engine.
            src = kernels_from
            assert (src.model is model and src.params is params
                    and src.cache.page_size == page_size
                    and src.backend == self.backend), \
                "kernel sharing requires identical model/params/page_size/backend"
            self._layer_fn = src._layer_fn
            self._embed_fn = src._embed_fn
            self._logits_fn = src._logits_fn
            self._sample_fn = src._sample_fn
            self._decode_fns = src._decode_fns
            self._layer_params_cached = src._layer_params_cached
        else:
            self._layer_fn = self._build_layer_fn()
            self._embed_fn = jax.jit(lambda p, t: model._embed(p, t))
            self._logits_fn = jax.jit(lambda p, x: model._logits(p, x))
            self._sample_fn = jax.jit(sample_tokens)
            self._decode_fns: dict[tuple[int, int], Callable] = {}
            # per-layer params sliced once (not jax.tree.map per layer per prefill)
            self._layer_params_cached = [
                jax.tree.map(lambda a, i=i: a[i], params["layers"])
                for i in range(cfg.num_layers)]
        self._base_key = jax.random.PRNGKey(self.sampling.seed)
        self._sample_step = 0

    # ------------------------------------------------------------------
    # sampling
    # ------------------------------------------------------------------
    def set_sampling(self, rid: int, temperature: float, top_k: int = 0) -> None:
        """Per-request override of the engine-default sampling params."""
        self.req_sampling[rid] = (temperature, top_k)

    def _sampling_arrays(self, rids: list[int], pad_to: int):
        d = (self.sampling.temperature, self.sampling.top_k)
        temps = np.zeros(pad_to, np.float32)
        topks = np.zeros(pad_to, np.int32)
        for i, r in enumerate(rids):
            temps[i], topks[i] = self.req_sampling.get(r, d)
        return temps, topks

    def _next_key(self):
        self._sample_step += 1
        return self._base_key, np.int32(self._sample_step)

    # ------------------------------------------------------------------
    # layer-interruptible prefill
    # ------------------------------------------------------------------
    def _build_layer_fn(self):
        cfg = self.cfg
        impl = impl_for_backend(self.backend)

        @jax.jit
        def layer_fn(lp, x, positions):
            h = _norm(cfg, lp["ln1"], x)
            a, (k, v) = attention.attn_prefill(
                lp["attn"], h, positions, cfg, window=cfg.sliding_window,
                impl=impl)
            if cfg.use_post_norm:
                a = _norm(cfg, lp["post_ln1"], a)
            x = x + a
            h = _norm(cfg, lp["ln2"], x)
            if cfg.is_moe:
                m, _ = moe_lib.moe_mlp(lp["moe"], h, cfg, groups=1)
            else:
                m = layers.mlp(lp["mlp"], h, cfg.mlp_act)
            if cfg.use_post_norm:
                m = _norm(cfg, lp["post_ln2"], m)
            return x + m, k, v

        return layer_fn

    def _layer_params(self, i: int):
        return self._layer_params_cached[i]

    def add_request(self, req: Request, prompt_tokens: list[int]) -> None:
        assert len(prompt_tokens) == req.prompt_len
        self.requests[req.rid] = req
        self.token_buf[req.rid] = TokenRing(
            prompt_tokens, capacity=req.prompt_len + req.output_len + 8)

    def _flush_prefill_kv(self, rid: int, start_layer: int, ks, vs) -> None:
        """Land buffered per-layer K/V in one donated scatter."""
        if ks:
            self.cache.write_prefill_layers(
                rid, start_layer, jnp.stack(ks), jnp.stack(vs))

    def prefill(self, rid: int, *, should_preempt: Callable[[], bool] | None = None,
                max_new_pages: bool = True) -> str:
        """Run (or resume) prefill for one request, checking the preemption
        callback between transformer layers. Returns "done" | "preempted"."""
        t0 = time.perf_counter()
        req = self.requests[rid]
        cfg = self.cfg
        if rid in self.partial:
            part = self.partial.pop(rid)
            x, start_layer, tokens = part.x, part.layer, part.tokens
        else:
            tokens = np.asarray(self.token_buf[rid][: req.prompt_len], np.int32)
            self.cache.ensure(rid, req.prompt_len)
            x = self._embed_fn(self.params, jnp.asarray(tokens)[None])
            start_layer = 0
        S = tokens.shape[0]
        positions = jnp.arange(S)[None]
        req.phase = Phase.PREFILLING
        ks, vs = [], []   # per-layer KV buffered; flushed once per segment
        for li in range(start_layer, cfg.num_layers):
            x, k, v = self._layer_fn(self._layer_params(li), x, positions)
            ks.append(k[0])
            vs.append(v[0])
            req.prefill_layers_done = li + 1
            if should_preempt is not None and li < cfg.num_layers - 1 and should_preempt():
                self._flush_prefill_kv(rid, start_layer, ks, vs)
                self.partial[rid] = PartialPrefill(rid, x, li + 1, tokens)
                self.stats.preemptions += 1
                self.stats.prefill_seconds += time.perf_counter() - t0
                return "preempted"
        self._flush_prefill_kv(rid, start_layer, ks, vs)
        # first token from the last hidden state, sampled on device
        logits = self._logits_fn(self.params, x[:, -1])
        temps, topks = self._sampling_arrays([rid], 1)
        if temps[0] > 0:
            key, step = self._next_key()
            nxt = int(self._sample_fn(logits, jax.random.fold_in(key, step),
                                      jnp.asarray(temps), jnp.asarray(topks))[0])
        else:
            nxt = int(jnp.argmax(logits, -1)[0])
        self.token_buf[rid].append(nxt)
        req.generated = 1
        req.phase = Phase.DECODING
        self.stats.prefill_tokens += S
        self.stats.prefill_seconds += time.perf_counter() - t0
        return "done"

    def abort_prefill(self, rid: int) -> None:
        """Discard partial prefill (offline request pushed back to queue)."""
        self.partial.pop(rid, None)
        self.cache.free(rid)
        req = self.requests[rid]
        req.recompute_tokens += req.prompt_len
        req.prefill_layers_done = 0
        req.phase = Phase.QUEUED

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def _bucket(self, n: int) -> int:
        for b in self.decode_buckets:
            if n <= b:
                return b
        return self.decode_buckets[-1]

    @staticmethod
    def pad_pages(pages: int) -> int:
        """Pad a decode batch's page dimension to a power of two — bounds the
        set of (bucket, pages) jit variants. Shared with the benchmarks."""
        return 1 << (pages - 1).bit_length()

    def _decode_fn(self, bucket: int, pages: int, sampled: bool = False):
        """``sampled=False`` specializes the step to plain argmax — the
        all-greedy default never pays the sampler's full-vocab sort."""
        key = (bucket, pages, sampled)
        if key in self._decode_fns:
            return self._decode_fns[key]
        cfg = self.cfg
        model = self.model
        use_ref, interpret = backend_flags(self.backend)

        @functools.partial(jax.jit, donate_argnums=(5, 6))
        def step(params, tokens, positions, tables, lengths, k_pool, v_pool,
                 key, sample_step, temps, top_ks):
            x = model._embed(params, tokens[:, None])
            hd = cfg.head_dim_
            page_ids = jnp.take_along_axis(
                tables, (positions // self.cache.page_size)[:, None], axis=1)[:, 0]
            offs = positions % self.cache.page_size

            # The pools ride in the scan CARRY (not xs/ys): per-layer writes
            # are dynamic-update-slices into the carried buffer, which XLA
            # keeps in place inside the loop and aliases to the donated
            # inputs — the xs/ys formulation forced a full-pool copy per
            # step because ys are always freshly stacked.
            def body(carry, inp):
                x, kpool, vpool = carry
                lp, li = inp
                h = _norm(cfg, lp["ln1"], x)
                k_new, v_new = attention.project_kv_for_cache(lp["attn"], h, positions, cfg)
                # round through cfg dtype, then store in the pool's storage
                # dtype (f32 on CPU — see PagedKVCache) for bit-parity with
                # the native-dtype pool layout
                kpool = kpool.at[li, page_ids, offs].set(
                    k_new[:, 0].astype(cfg.jnp_dtype).astype(kpool.dtype))
                vpool = vpool.at[li, page_ids, offs].set(
                    v_new[:, 0].astype(cfg.jnp_dtype).astype(vpool.dtype))
                q = layers.dense(lp["attn"]["wq"], h[:, 0]).reshape(
                    -1, cfg.num_heads, hd)
                if cfg.qk_norm:
                    q = layers.rmsnorm(lp["attn"]["q_norm"], q, cfg.norm_eps)
                q = layers.apply_rope(q[:, None], positions[:, None], cfg.rope_theta)[:, 0]
                # compact the layer's KV to just this batch's pages: a gather
                # of B*P pages (+ renumbered tables) instead of slicing the
                # full num_pages pool out of the carried buffer per layer
                B, P = tables.shape
                page = kpool.shape[2]
                comp_k = kpool[li, tables].reshape(B * P, page, *kpool.shape[3:])
                comp_v = vpool[li, tables].reshape(B * P, page, *vpool.shape[3:])
                local_tables = jnp.arange(B * P, dtype=jnp.int32).reshape(B, P)
                a = paged_attention(q, comp_k, comp_v, local_tables, lengths,
                                    num_kv_heads=cfg.num_kv_heads,
                                    logit_softcap=cfg.attn_logit_softcap,
                                    use_ref=use_ref, interpret=interpret)
                a = layers.dense(lp["attn"]["wo"], a.reshape(a.shape[0], 1, -1))
                if cfg.use_post_norm:
                    a = _norm(cfg, lp["post_ln1"], a)
                x = x + a
                h = _norm(cfg, lp["ln2"], x)
                if cfg.is_moe:
                    m, _ = moe_lib.moe_mlp(lp["moe"], h, cfg, groups=1)
                else:
                    m = layers.mlp(lp["mlp"], h, cfg.mlp_act)
                if cfg.use_post_norm:
                    m = _norm(cfg, lp["post_ln2"], m)
                return (x + m, kpool, vpool), None

            (x, k_pool, v_pool), _ = jax.lax.scan(
                body, (x, k_pool, v_pool),
                (params["layers"], jnp.arange(cfg.num_layers)))
            logits = model._logits(params, x[:, 0])
            if sampled:
                nxt = sample_tokens(logits, jax.random.fold_in(key, sample_step),
                                    temps, top_ks)
            else:
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return nxt, k_pool, v_pool

        self._decode_fns[key] = step
        return step

    def decode_step(self, rids: list[int]) -> dict[int, int]:
        """One continuous-batching decode iteration for the given requests;
        batches larger than the biggest bucket run as multiple bucket-sized
        chunks (no request is ever silently dropped). Returns rid -> new
        token for every rid passed."""
        if not rids:
            return {}
        out: dict[int, int] = {}
        max_bucket = self.decode_buckets[-1]
        for i in range(0, len(rids), max_bucket):
            out.update(self._decode_chunk(rids[i: i + max_bucket]))
        return out

    def _decode_chunk(self, rids: list[int]) -> dict[int, int]:
        t0 = time.perf_counter()
        B = len(rids)
        bucket = self._bucket(B)
        for r in rids:
            req = self.requests[r]
            self.cache.ensure(r, req.context_len)
        pages = self.pad_pages(max(len(self.cache.tables[r]) for r in rids))
        tables = self.cache.batch_tables(rids, pad_to=pages)
        # the input token is the last one in the buffer; its position is
        # context_len - 1 and the cache covers [0, context_len) after writing
        positions = np.array([self.requests[r].context_len - 1 for r in rids], np.int32)
        tokens = np.array([self.token_buf[r][int(pos)] for r, pos in zip(rids, positions)],
                          np.int32)
        lengths = positions + 1
        temps, topks = self._sampling_arrays(rids, bucket)
        pad = bucket - B
        if pad:
            tables = np.pad(tables, ((0, pad), (0, 0)))
            positions = np.pad(positions, (0, pad))
            tokens = np.pad(tokens, (0, pad))
            lengths = np.pad(lengths, (0, pad), constant_values=1)
        sampled = (self.sampling.temperature > 0
                   or any(r in self.req_sampling for r in rids))
        fn = self._decode_fn(bucket, pages, sampled)
        key, sample_step = self._next_key()
        nxt_dev, self.cache.k_pool, self.cache.v_pool = fn(
            self.params, jnp.asarray(tokens), jnp.asarray(positions),
            jnp.asarray(tables), jnp.asarray(lengths),
            self.cache.k_pool, self.cache.v_pool,
            key, sample_step, jnp.asarray(temps), jnp.asarray(topks))
        nxt = np.asarray(nxt_dev)   # (bucket,) ids — the only device->host sync
        out = {}
        dt = time.perf_counter() - t0
        for i, r in enumerate(rids):
            req = self.requests[r]
            tok = int(nxt[i])
            self.token_buf[r].append(tok)
            req.generated += 1
            req.decode_time_sum += dt
            out[r] = tok
            if req.done:
                req.phase = Phase.FINISHED
                self.cache.free(r)
                self.req_sampling.pop(r, None)
        self.stats.decode_tokens += B
        self.stats.decode_steps += 1
        self.stats.decode_seconds += dt
        return out

    # ------------------------------------------------------------------
    def evict(self, rid: int) -> None:
        """Evict a decoding request (offline victim): free pages; it must
        re-prefill (recompute) later."""
        req = self.requests[rid]
        req.recompute_tokens += req.context_len
        req.evictions += 1
        req.phase = Phase.EVICTED
        self.cache.free(rid)
        self.stats.evictions += 1

    def migrate_out(self, rid: int):
        """Export KV for migration to another engine (RDMA->ICI analogue)."""
        k, v, n = self.cache.export_request(rid)
        self.cache.free(rid)
        return k, v, n

    def migrate_in(self, rid: int, req: Request, tokens, k, v, n: int,
                   sampling: tuple[float, int] | None = None) -> None:
        self.requests[rid] = req
        toks = list(tokens)
        self.token_buf[rid] = TokenRing(
            toks, capacity=len(toks) + max(req.remaining, 0) + 8)
        if sampling is not None:
            self.req_sampling[rid] = sampling
        self.cache.import_request(rid, k, v, n)
        req.phase = Phase.DECODING
