"""Paged KV cache: block allocator + JAX page pools (vLLM-style, §2.1).

The pool is a pair of (L, num_pages, page_size, Hkv, hd) arrays; per-request
page lists (block tables) live Python-side in the engine. Non-contiguous
paging is what makes continuous batching + preemption cheap: evicting a
request is just returning its pages to the free list.

Hot-path note: every pool write goes through a *jitted, donated* scatter
(``_scatter_layers``). Donation aliases the input pool buffers to the
outputs, so XLA updates the pool in place instead of copying the full
L × num_pages × page × Hkv × hd arrays on every prefill-layer write — the
dominant cost of the un-donated seed path. Prefill additionally buffers all
layers' K/V and lands them in a single scatter per prefill (or per
preemption segment) instead of one dispatch per layer.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


class OutOfPagesError(RuntimeError):
    pass


class TransferIntegrityError(RuntimeError):
    """A migrated KV payload failed its checksum at the destination."""


def transfer_checksum(k, v) -> float:
    """Order-independent integrity checksum of a KV transfer payload.

    f64 accumulation over both halves — cheap at page-pool scale, and any
    single corrupted value moves the sum, which is all the deterministic
    fault injector's bit-flip model needs. Computed at export, verified at
    import (``verify_transfer``) BEFORE any destination state changes."""
    return float(np.abs(np.asarray(k, np.float64)).sum()
                 + np.abs(np.asarray(v, np.float64)).sum())


def verify_transfer(k, v, checksum: float, rtol: float = 1e-9) -> None:
    got = transfer_checksum(k, v)
    if abs(got - checksum) > rtol * max(abs(checksum), 1.0):
        raise TransferIntegrityError(
            f"KV transfer checksum mismatch: expected {checksum!r}, "
            f"got {got!r}")


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _scatter_layers(k_pool, v_pool, layer_ids, page_ids, offs, k, v):
    """Scatter S positions of n layers into donated pools in one op.

    k/v: (n, S, Hkv, hd); layer_ids (n,); page_ids/offs (S,). The donated
    pools come back aliased — callers must rebind and drop the old refs.
    """
    idx = (layer_ids[:, None], page_ids[None, :], offs[None, :])
    k_pool = k_pool.at[idx].set(k.astype(k_pool.dtype))
    v_pool = v_pool.at[idx].set(v.astype(v_pool.dtype))
    return k_pool, v_pool


class BlockAllocator:
    def __init__(self, num_pages: int, reserved: int = 0):
        """``reserved`` low pages are never handed out — page 0 serves as the
        trash page that padded decode-batch rows scatter into."""
        self.num_pages = num_pages
        self._free = list(range(num_pages - 1, reserved - 1, -1))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> list[int]:
        if n > len(self._free):
            raise OutOfPagesError(f"need {n} pages, {len(self._free)} free")
        return [self._free.pop() for _ in range(n)]

    def free(self, pages: list[int]) -> None:
        self._free.extend(pages)


@dataclass
class PagedKVCache:
    cfg: ModelConfig
    num_pages: int
    page_size: int = 16
    k_pool: jnp.ndarray = field(init=False)
    v_pool: jnp.ndarray = field(init=False)
    allocator: BlockAllocator = field(init=False)
    tables: dict[int, list[int]] = field(default_factory=dict)
    lengths: dict[int, int] = field(default_factory=dict)

    def __post_init__(self):
        cfg = self.cfg
        shape = (cfg.num_layers, self.num_pages, self.page_size,
                 cfg.num_kv_heads, cfg.head_dim_)
        # Storage dtype: XLA CPU lowers 16-bit-float scatters to a scalar
        # emulation loop (~1000x slower than f32 — measured in
        # bench_decode_hotpath's development); on CPU we store the pool in
        # f32 but ROUND every value through cfg.jnp_dtype before storing, so
        # the cached bits (and therefore tokens) are identical to the
        # bf16-pool layout used on TPU.
        self.value_dtype = cfg.jnp_dtype
        if (jax.default_backend() == "cpu"
                and jnp.dtype(cfg.jnp_dtype).itemsize < 4):
            self.storage_dtype = jnp.float32
        else:
            self.storage_dtype = cfg.jnp_dtype
        self.k_pool = jnp.zeros(shape, self.storage_dtype)
        self.v_pool = jnp.zeros(shape, self.storage_dtype)
        self.allocator = BlockAllocator(self.num_pages, reserved=1)

    # ------------------------------------------------------------------
    def pages_for(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    def ensure(self, rid: int, target_len: int) -> None:
        """Grow rid's block table to cover target_len tokens."""
        table = self.tables.setdefault(rid, [])
        need = self.pages_for(target_len) - len(table)
        if need > 0:
            table.extend(self.allocator.alloc(need))
        self.lengths[rid] = target_len

    def free(self, rid: int) -> int:
        """Release all pages of a request (completion or eviction)."""
        pages = self.tables.pop(rid, [])
        self.allocator.free(pages)
        return self.lengths.pop(rid, 0)

    def can_fit(self, tokens: int) -> bool:
        return self.pages_for(tokens) <= self.allocator.free_pages

    # ------------------------------------------------------------------
    def _scatter_index(self, rid: int, S: int) -> tuple[np.ndarray, np.ndarray]:
        table = np.asarray(self.tables[rid], np.int32)
        pos = np.arange(S)
        return table[pos // self.page_size], (pos % self.page_size).astype(np.int32)

    def write_prefill_layer(self, rid: int, layer: int, k, v) -> None:
        """Scatter one layer's prefill K/V (S, Hkv, hd) into the pool."""
        self.write_prefill_layers(rid, layer, k[None], v[None])

    def write_prefill_layers(self, rid: int, start_layer: int, k, v) -> None:
        """Scatter ``n`` consecutive layers' prefill K/V in one donated op.

        k/v: (n, S, Hkv, hd) — layer-buffered prefill output, landed once
        per prefill instead of once per layer."""
        n, S = k.shape[0], k.shape[1]
        page_ids, offs = self._scatter_index(rid, S)
        layer_ids = np.arange(start_layer, start_layer + n, dtype=np.int32)
        self.k_pool, self.v_pool = _scatter_layers(
            self.k_pool, self.v_pool, layer_ids, page_ids, offs,
            jnp.asarray(k).astype(self.value_dtype),
            jnp.asarray(v).astype(self.value_dtype))

    def batch_tables(self, rids: list[int], pad_to: int | None = None) -> np.ndarray:
        """Dense (B, P) int32 table for a decode batch (padded with page 0 —
        masked out by lengths in the attention)."""
        P = pad_to or max(len(self.tables[r]) for r in rids)
        out = np.zeros((len(rids), P), np.int32)
        for i, r in enumerate(rids):
            t = self.tables[r]
            out[i, : len(t)] = t
        return out

    def export_request(self, rid: int) -> tuple[np.ndarray, np.ndarray, int]:
        """Gather a request's KV (for migration): (L, S, Hkv, hd) x2 + len."""
        table = np.asarray(self.tables[rid], np.int32)
        L = self.cfg.num_layers
        k = np.asarray(self.k_pool[:, table]).reshape(
            L, -1, self.cfg.num_kv_heads, self.cfg.head_dim_)
        v = np.asarray(self.v_pool[:, table]).reshape(
            L, -1, self.cfg.num_kv_heads, self.cfg.head_dim_)
        n = self.lengths[rid]
        return k[:, :n], v[:, :n], n

    def import_request(self, rid: int, k, v, n: int) -> None:
        """Write migrated KV (L, n, Hkv, hd) into freshly allocated pages."""
        self.ensure(rid, n)
        page_ids, offs = self._scatter_index(rid, n)
        layer_ids = np.arange(self.cfg.num_layers, dtype=np.int32)
        self.k_pool, self.v_pool = _scatter_layers(
            self.k_pool, self.v_pool, layer_ids, page_ids, offs,
            jnp.asarray(k).astype(self.value_dtype),
            jnp.asarray(v).astype(self.value_dtype))
