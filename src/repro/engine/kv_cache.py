"""Paged KV cache: block allocator + JAX page pools (vLLM-style, §2.1).

The pool is a pair of (L, num_pages, page_size, Hkv, hd) arrays; per-request
page lists (block tables) live Python-side in the engine. Non-contiguous
paging is what makes continuous batching + preemption cheap: evicting a
request is just returning its pages to the free list.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


class OutOfPagesError(RuntimeError):
    pass


class BlockAllocator:
    def __init__(self, num_pages: int, reserved: int = 0):
        """``reserved`` low pages are never handed out — page 0 serves as the
        trash page that padded decode-batch rows scatter into."""
        self.num_pages = num_pages
        self._free = list(range(num_pages - 1, reserved - 1, -1))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> list[int]:
        if n > len(self._free):
            raise OutOfPagesError(f"need {n} pages, {len(self._free)} free")
        return [self._free.pop() for _ in range(n)]

    def free(self, pages: list[int]) -> None:
        self._free.extend(pages)


@dataclass
class PagedKVCache:
    cfg: ModelConfig
    num_pages: int
    page_size: int = 16
    k_pool: jnp.ndarray = field(init=False)
    v_pool: jnp.ndarray = field(init=False)
    allocator: BlockAllocator = field(init=False)
    tables: dict[int, list[int]] = field(default_factory=dict)
    lengths: dict[int, int] = field(default_factory=dict)

    def __post_init__(self):
        cfg = self.cfg
        shape = (cfg.num_layers, self.num_pages, self.page_size,
                 cfg.num_kv_heads, cfg.head_dim_)
        self.k_pool = jnp.zeros(shape, cfg.jnp_dtype)
        self.v_pool = jnp.zeros(shape, cfg.jnp_dtype)
        self.allocator = BlockAllocator(self.num_pages, reserved=1)

    # ------------------------------------------------------------------
    def pages_for(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    def ensure(self, rid: int, target_len: int) -> None:
        """Grow rid's block table to cover target_len tokens."""
        table = self.tables.setdefault(rid, [])
        need = self.pages_for(target_len) - len(table)
        if need > 0:
            table.extend(self.allocator.alloc(need))
        self.lengths[rid] = target_len

    def free(self, rid: int) -> int:
        """Release all pages of a request (completion or eviction)."""
        pages = self.tables.pop(rid, [])
        self.allocator.free(pages)
        return self.lengths.pop(rid, 0)

    def can_fit(self, tokens: int) -> bool:
        return self.pages_for(tokens) <= self.allocator.free_pages

    # ------------------------------------------------------------------
    def write_prefill_layer(self, rid: int, layer: int, k, v) -> None:
        """Scatter one layer's prefill K/V (S, Hkv, hd) into the pool."""
        S = k.shape[0]
        table = np.asarray(self.tables[rid], np.int32)
        pos = np.arange(S)
        page_ids = table[pos // self.page_size]
        offs = pos % self.page_size
        self.k_pool = self.k_pool.at[layer, page_ids, offs].set(
            k.astype(self.k_pool.dtype))
        self.v_pool = self.v_pool.at[layer, page_ids, offs].set(
            v.astype(self.v_pool.dtype))

    def batch_tables(self, rids: list[int], pad_to: int | None = None) -> np.ndarray:
        """Dense (B, P) int32 table for a decode batch (padded with page 0 —
        masked out by lengths in the attention)."""
        P = pad_to or max(len(self.tables[r]) for r in rids)
        out = np.zeros((len(rids), P), np.int32)
        for i, r in enumerate(rids):
            t = self.tables[r]
            out[i, : len(t)] = t
        return out

    def export_request(self, rid: int) -> tuple[np.ndarray, np.ndarray, int]:
        """Gather a request's KV (for migration): (L, S, Hkv, hd) x2 + len."""
        table = np.asarray(self.tables[rid], np.int32)
        L = self.cfg.num_layers
        k = np.asarray(self.k_pool[:, table]).reshape(
            L, -1, self.cfg.num_kv_heads, self.cfg.head_dim_)
        v = np.asarray(self.v_pool[:, table]).reshape(
            L, -1, self.cfg.num_kv_heads, self.cfg.head_dim_)
        n = self.lengths[rid]
        return k[:, :n], v[:, :n], n

    def import_request(self, rid: int, k, v, n: int) -> None:
        """Write migrated KV (L, n, Hkv, hd) into freshly allocated pages."""
        self.ensure(rid, n)
        table = np.asarray(self.tables[rid], np.int32)
        pos = np.arange(n)
        page_ids = table[pos // self.page_size]
        offs = pos % self.page_size
        self.k_pool = self.k_pool.at[:, page_ids, offs].set(
            jnp.asarray(k, self.k_pool.dtype))
        self.v_pool = self.v_pool.at[:, page_ids, offs].set(
            jnp.asarray(v, self.v_pool.dtype))
