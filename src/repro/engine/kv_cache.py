"""Paged KV cache: block allocator + JAX page pools (vLLM-style, §2.1).

The pool is a pair of (L, num_pages, page_size, Hkv, hd) arrays; per-request
page lists (block tables) live Python-side in the engine. Non-contiguous
paging is what makes continuous batching + preemption cheap: evicting a
request is just returning its pages to the free list.

Pages are REFCOUNTED: ``BlockAllocator`` tracks owners per page, and a
``RadixPrefixCache`` (block-aligned radix tree keyed on token ids) lets a
new request claim another request's already-computed prefix pages by
bumping refcounts — cross-request KV reuse, sglang-style. Sharing is
copy-on-write by construction: matches are capped below the prompt length
and rounded down to a page boundary, so every position a request writes
(prefill suffix and all decode tokens) lands on pages it owns exclusively;
partial-page tails are recomputed, never shared.

Hot-path note: every pool write goes through a *jitted, donated* scatter
(``_scatter_layers``). Donation aliases the input pool buffers to the
outputs, so XLA updates the pool in place instead of copying the full
L × num_pages × page × Hkv × hd arrays on every prefill-layer write — the
dominant cost of the un-donated seed path. Prefill additionally buffers all
layers' K/V and lands them in a single scatter per prefill (or per
preemption segment) instead of one dispatch per layer.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


class OutOfPagesError(RuntimeError):
    pass


class DoubleFreeError(RuntimeError):
    """A page was released that the allocator does not consider live —
    double-free, unknown index, or a reserved page. Silently extending the
    free list here (the pre-refcount behaviour) would hand the same page to
    two owners; with shared pages that corrupts a *sibling's* KV."""


class TransferIntegrityError(RuntimeError):
    """A migrated KV payload failed its checksum at the destination."""


def transfer_checksum(k, v) -> float:
    """Order-independent integrity checksum of a KV transfer payload.

    f64 accumulation over both halves — cheap at page-pool scale, and any
    single corrupted value moves the sum, which is all the deterministic
    fault injector's bit-flip model needs. Computed at export, verified at
    import (``verify_transfer``) BEFORE any destination state changes."""
    return float(np.abs(np.asarray(k, np.float64)).sum()
                 + np.abs(np.asarray(v, np.float64)).sum())


def verify_transfer(k, v, checksum: float, rtol: float = 1e-9) -> None:
    got = transfer_checksum(k, v)
    if abs(got - checksum) > rtol * max(abs(checksum), 1.0):
        raise TransferIntegrityError(
            f"KV transfer checksum mismatch: expected {checksum!r}, "
            f"got {got!r}")


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _scatter_layers(k_pool, v_pool, layer_ids, page_ids, offs, k, v):
    """Scatter S positions of n layers into donated pools in one op.

    k/v: (n, S, Hkv, hd); layer_ids (n,); page_ids/offs (S,). The donated
    pools come back aliased — callers must rebind and drop the old refs.
    """
    idx = (layer_ids[:, None], page_ids[None, :], offs[None, :])
    k_pool = k_pool.at[idx].set(k.astype(k_pool.dtype))
    v_pool = v_pool.at[idx].set(v.astype(v_pool.dtype))
    return k_pool, v_pool


class BlockAllocator:
    """Refcounted page allocator. ``alloc`` hands out pages at refcount 1;
    ``incref`` lets a second owner (another request's block table, or the
    radix prefix cache) share a page copy-on-write-style; ``free`` is a
    decref — the page returns to the free list only when its LAST owner
    releases it, so no sibling can ever lose a shared page out from under
    itself."""

    def __init__(self, num_pages: int, reserved: int = 0):
        """``reserved`` low pages are never handed out — page 0 serves as the
        trash page that padded decode-batch rows scatter into."""
        self.num_pages = num_pages
        self.reserved = reserved
        self._free = list(range(num_pages - 1, reserved - 1, -1))
        self._refs = [0] * num_pages

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def live_pages(self) -> int:
        """Pages currently held by >= 1 owner (excludes reserved + free)."""
        return self.num_pages - self.reserved - len(self._free)

    def refcount(self, page: int) -> int:
        return self._refs[page]

    def alloc(self, n: int) -> list[int]:
        if n > len(self._free):
            raise OutOfPagesError(f"need {n} pages, {len(self._free)} free")
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._refs[p] = 1
        return pages

    def incref(self, pages: list[int]) -> None:
        """Add an owner to already-live pages (prefix-cache claims)."""
        for p in pages:
            if self._refs[p] <= 0:
                raise DoubleFreeError(
                    f"incref on non-live page {p} (refcount "
                    f"{self._refs[p]}): only resident pages can be shared")
            self._refs[p] += 1

    def free(self, pages: list[int]) -> None:
        """Drop one owner per page; recycle pages whose refcount hits 0.
        Raises ``DoubleFreeError`` on unknown/reserved/already-free pages
        instead of silently corrupting the free list."""
        for p in pages:
            if not self.reserved <= p < self.num_pages:
                raise DoubleFreeError(
                    f"free of unknown page {p} (valid range "
                    f"[{self.reserved}, {self.num_pages}))")
            if self._refs[p] <= 0:
                raise DoubleFreeError(f"double free of page {p}")
            self._refs[p] -= 1
            if self._refs[p] == 0:
                self._free.append(p)


class _PrefixNode:
    """One full KV page in the radix tree, keyed by the token ids it holds."""

    __slots__ = ("key", "page", "children", "parent", "stamp")

    def __init__(self, key, page, parent, stamp):
        self.key = key                 # tuple of page_size token ids
        self.page = page               # pool page index (None for the root)
        self.children: dict[tuple, _PrefixNode] = {}
        self.parent = parent
        self.stamp = stamp             # logical LRU clock (deterministic)


class RadixPrefixCache:
    """Block-aligned radix/prefix tree over resident token sequences
    (sglang-style cross-request KV reuse).

    Each node is one FULL page of ``page_size`` token ids, child edges keyed
    by the next page's token tuple — so matching an incoming prompt is a
    dict walk, page by page, and a hit hands back pool pages whose KV bits
    are identical to what a cold prefill would compute (prefix KV depends
    only on token ids + absolute positions, and every pool write is rounded
    through the model dtype). The tree holds its OWN reference on every
    resident page (``BlockAllocator.incref`` at insert), so pages survive
    their computing request and are released only by ``evict``/LRU pressure.
    Partial-page tails are never inserted and never shared — the COW rule:
    any position a request might still write lives on a private page.
    """

    def __init__(self, allocator: BlockAllocator, page_size: int):
        self.allocator = allocator
        self.page_size = page_size
        self.root = _PrefixNode((), None, None, 0)
        self._clock = 0
        # cumulative counters (deterministic; surfaced via runtime summary)
        self.evictions = 0         # tree pages dropped under pool pressure
        self.inserted_pages = 0    # pages adopted into the tree, cumulative

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    @property
    def resident_pages(self) -> int:
        """Pages currently referenced by the tree."""
        n, stack = 0, [self.root]
        while stack:
            node = stack.pop()
            n += len(node.children)
            stack.extend(node.children.values())
        return n

    def reclaimable(self) -> int:
        """Tree pages only the tree still references (refcount == 1) — the
        pages ``evict`` could return to the free list right now."""
        n, stack = 0, [self.root]
        while stack:
            node = stack.pop()
            for ch in node.children.values():
                if self.allocator.refcount(ch.page) == 1:
                    n += 1
                stack.append(ch)
        return n

    def match(self, tokens, limit: int | None = None,
              touch: bool = True) -> tuple[list[int], int]:
        """Longest block-aligned cached prefix of ``tokens``.

        Returns (pages, matched_tokens); matching stops at ``limit`` tokens
        (callers pass ``prompt_len - 1`` so at least one suffix token is
        always recomputed — its logits produce the first output token, and
        the suffix then starts exactly on a page boundary). Walked nodes'
        LRU stamps are refreshed unless ``touch=False`` (planning peeks —
        e.g. the gating cost model — must not perturb eviction order). The
        caller must claim the pages (``PagedKVCache.adopt``) before
        anything else can evict them."""
        toks = [int(t) for t in tokens]
        cap = len(toks) if limit is None else min(limit, len(toks))
        pages: list[int] = []
        node, matched = self.root, 0
        while matched + self.page_size <= cap:
            key = tuple(toks[matched: matched + self.page_size])
            child = node.children.get(key)
            if child is None:
                break
            if touch:
                child.stamp = self._tick()
            pages.append(child.page)
            node = child
            matched += self.page_size
        return pages, matched

    def insert(self, tokens, table: list[int]) -> int:
        """Register a prefilled request's FULL pages in the tree. Pages
        whose prefix path already exists are skipped (the existing copy
        wins — the request keeps its private duplicate, freed with it);
        new nodes take a tree-owned reference (incref) so the KV outlives
        the request. Returns the number of pages adopted."""
        toks = [int(t) for t in tokens]
        node, adopted = self.root, 0
        for i in range(len(toks) // self.page_size):
            key = tuple(toks[i * self.page_size: (i + 1) * self.page_size])
            child = node.children.get(key)
            if child is None:
                page = table[i]
                self.allocator.incref([page])
                child = _PrefixNode(key, page, node, self._tick())
                node.children[key] = child
                adopted += 1
                self.inserted_pages += 1
            else:
                child.stamp = self._tick()
            node = child
        return adopted

    def evict(self, need_pages: int) -> int:
        """Drop LRU leaves until ``need_pages`` pages have actually returned
        to the free list (or the tree is empty). Unshared leaves
        (refcount == 1: dropping frees a page NOW) are always preferred;
        a shared leaf is dropped only when no unshared one exists — that
        frees nothing immediately (the sibling request keeps its reference)
        but unblocks the leaf's ancestors for the next pass. Never touches
        a page's other owners: eviction here is a decref, nothing more."""
        freed = 0
        while freed < need_pages:
            leaves: list[_PrefixNode] = []
            stack = list(self.root.children.values())
            while stack:
                node = stack.pop()
                if node.children:
                    stack.extend(node.children.values())
                else:
                    leaves.append(node)
            if not leaves:
                break
            unshared = [l for l in leaves
                        if self.allocator.refcount(l.page) == 1]
            victim = min(unshared or leaves, key=lambda l: (l.stamp, l.page))
            was_unshared = self.allocator.refcount(victim.page) == 1
            del victim.parent.children[victim.key]
            self.allocator.free([victim.page])
            self.evictions += 1
            if was_unshared:
                freed += 1
        return freed

    def clear(self) -> None:
        """Drop the whole tree WITHOUT touching the allocator — the crash
        path, where the engine's pool and bookkeeping are gone wholesale
        (recovery recomputes from the frontend prompt log)."""
        self.root = _PrefixNode((), None, None, 0)

    def release_all(self) -> int:
        """Drop the whole tree and RELEASE the tree's reference on every
        node page — the graceful-drain path, where the allocator stays
        authoritative and must end with zero live pages. Returns the number
        of references released. (Contrast ``clear``, which abandons the
        refcounts because the crashed pool is being discarded wholesale.)"""
        released = 0
        stack = list(self.root.children.values())
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            self.allocator.free([node.page])
            released += 1
        self.root = _PrefixNode((), None, None, 0)
        return released


@dataclass
class PagedKVCache:
    cfg: ModelConfig
    num_pages: int
    page_size: int = 16
    enable_prefix_cache: bool = False
    k_pool: jnp.ndarray = field(init=False)
    v_pool: jnp.ndarray = field(init=False)
    allocator: BlockAllocator = field(init=False)
    prefix: RadixPrefixCache | None = field(init=False, default=None)
    tables: dict[int, list[int]] = field(default_factory=dict)
    lengths: dict[int, int] = field(default_factory=dict)

    def __post_init__(self):
        cfg = self.cfg
        shape = (cfg.num_layers, self.num_pages, self.page_size,
                 cfg.num_kv_heads, cfg.head_dim_)
        # Storage dtype: XLA CPU lowers 16-bit-float scatters to a scalar
        # emulation loop (~1000x slower than f32 — measured in
        # bench_decode_hotpath's development); on CPU we store the pool in
        # f32 but ROUND every value through cfg.jnp_dtype before storing, so
        # the cached bits (and therefore tokens) are identical to the
        # bf16-pool layout used on TPU.
        self.value_dtype = cfg.jnp_dtype
        if (jax.default_backend() == "cpu"
                and jnp.dtype(cfg.jnp_dtype).itemsize < 4):
            self.storage_dtype = jnp.float32
        else:
            self.storage_dtype = cfg.jnp_dtype
        self.k_pool = jnp.zeros(shape, self.storage_dtype)
        self.v_pool = jnp.zeros(shape, self.storage_dtype)
        self.allocator = BlockAllocator(self.num_pages, reserved=1)
        self.prefix = (RadixPrefixCache(self.allocator, self.page_size)
                       if self.enable_prefix_cache else None)

    # ------------------------------------------------------------------
    def pages_for(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    @property
    def available_pages(self) -> int:
        """Free pages plus prefix-cache pages reclaimable on demand —
        admission decisions should see both, since ``ensure`` evicts
        unshared tree pages before declaring the pool full."""
        free = self.allocator.free_pages
        if self.prefix is not None:
            free += self.prefix.reclaimable()
        return free

    def ensure(self, rid: int, target_len: int) -> None:
        """Grow rid's block table to cover target_len tokens."""
        table = self.tables.setdefault(rid, [])
        need = self.pages_for(target_len) - len(table)
        if need > 0:
            if need > self.allocator.free_pages and self.prefix is not None:
                # pool pressure: the prefix cache yields LRU unshared pages
                self.prefix.evict(need - self.allocator.free_pages)
            table.extend(self.allocator.alloc(need))
        self.lengths[rid] = target_len

    def adopt(self, rid: int, pages: list[int], matched_tokens: int) -> None:
        """Claim prefix-cache pages for a request: bump each page's
        refcount and seed the block table — a page-table update instead of
        ``matched_tokens`` of prefill compute. The request must not hold
        pages yet (claims happen before its first chunk)."""
        assert rid not in self.tables, f"request {rid} already has pages"
        self.allocator.incref(pages)
        self.tables[rid] = list(pages)
        self.lengths[rid] = matched_tokens

    def free(self, rid: int) -> int:
        """Release all pages of a request (completion or eviction). With
        refcounting this is a decref per page: pages shared with the prefix
        tree (or a sibling's table) stay resident for the other owners."""
        pages = self.tables.pop(rid, [])
        self.allocator.free(pages)
        return self.lengths.pop(rid, 0)

    def can_fit(self, tokens: int) -> bool:
        return self.pages_for(tokens) <= self.available_pages

    def shared_tokens(self, rid: int) -> int:
        """Tokens of ``rid`` whose pages are shared with another owner —
        evicting the request frees nothing for those, so eviction-victim
        selection should prefer requests with fewer of them."""
        table = self.tables.get(rid)
        if not table:
            return 0
        shared = sum(1 for p in table if self.allocator.refcount(p) > 1)
        return min(shared * self.page_size, self.lengths.get(rid, 0))

    # ------------------------------------------------------------------
    def _scatter_index(self, rid: int, S: int) -> tuple[np.ndarray, np.ndarray]:
        table = np.asarray(self.tables[rid], np.int32)
        pos = np.arange(S)
        return table[pos // self.page_size], (pos % self.page_size).astype(np.int32)

    def write_prefill_layer(self, rid: int, layer: int, k, v) -> None:
        """Scatter one layer's prefill K/V (S, Hkv, hd) into the pool."""
        self.write_prefill_layers(rid, layer, k[None], v[None])

    def write_prefill_layers(self, rid: int, start_layer: int, k, v) -> None:
        """Scatter ``n`` consecutive layers' prefill K/V in one donated op.

        k/v: (n, S, Hkv, hd) — layer-buffered prefill output, landed once
        per prefill instead of once per layer."""
        n, S = k.shape[0], k.shape[1]
        page_ids, offs = self._scatter_index(rid, S)
        layer_ids = np.arange(start_layer, start_layer + n, dtype=np.int32)
        self.k_pool, self.v_pool = _scatter_layers(
            self.k_pool, self.v_pool, layer_ids, page_ids, offs,
            jnp.asarray(k).astype(self.value_dtype),
            jnp.asarray(v).astype(self.value_dtype))

    def batch_tables(self, rids: list[int], pad_to: int | None = None) -> np.ndarray:
        """Dense (B, P) int32 table for a decode batch (padded with page 0 —
        masked out by lengths in the attention)."""
        P = pad_to or max(len(self.tables[r]) for r in rids)
        out = np.zeros((len(rids), P), np.int32)
        for i, r in enumerate(rids):
            t = self.tables[r]
            out[i, : len(t)] = t
        return out

    def export_request(self, rid: int) -> tuple[np.ndarray, np.ndarray, int]:
        """Gather a request's KV (for migration): (L, S, Hkv, hd) x2 + len."""
        table = np.asarray(self.tables[rid], np.int32)
        L = self.cfg.num_layers
        k = np.asarray(self.k_pool[:, table]).reshape(
            L, -1, self.cfg.num_kv_heads, self.cfg.head_dim_)
        v = np.asarray(self.v_pool[:, table]).reshape(
            L, -1, self.cfg.num_kv_heads, self.cfg.head_dim_)
        n = self.lengths[rid]
        return k[:, :n], v[:, :n], n

    def import_request(self, rid: int, k, v, n: int) -> None:
        """Write migrated KV (L, n, Hkv, hd) into freshly allocated pages."""
        self.ensure(rid, n)
        page_ids, offs = self._scatter_index(rid, n)
        layer_ids = np.arange(self.cfg.num_layers, dtype=np.int32)
        self.k_pool, self.v_pool = _scatter_layers(
            self.k_pool, self.v_pool, layer_ids, page_ids, offs,
            jnp.asarray(k).astype(self.value_dtype),
            jnp.asarray(v).astype(self.value_dtype))
