"""whisper-tiny [audio] — encoder-decoder, conv frontend stubbed.

Assigned: 4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865 [arXiv:2212.04356].
4 encoder + 4 decoder layers (whisper-tiny). The mel+conv frontend is a stub:
input_specs() provides frame embeddings. Decode-32k is architecturally
synthetic (real whisper caps at 448 positions) but lowers per the assignment;
long_500k is skipped (DESIGN §4).
"""
from repro.models.config import AUDIO, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family=AUDIO,
    num_layers=4,           # decoder layers
    encoder_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    head_dim=64,
    attn_bias=True,
    mlp_act="gelu_mlp",
    frontend="audio",
    num_frontend_tokens=1500,  # 30 s of audio at 50 frames/s
    tie_embeddings=True,
    norm_eps=1e-5,
    source="arXiv:2212.04356",
)
