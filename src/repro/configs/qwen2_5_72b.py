"""qwen2.5-72b — the paper's large evaluation model (§5.1.2).

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064, QKV bias
[arXiv:2407.10671].
"""
from repro.models.config import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-72b",
    family=DENSE,
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    head_dim=128,
    attn_bias=True,
    rope_theta=1e6,
    source="arXiv:2407.10671 / paper §5.1.2",
)
