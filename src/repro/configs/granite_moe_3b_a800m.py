"""granite-moe-3b-a800m [moe] — 40 experts, top-8.

Assigned: 32L d_model=1536 24H (GQA kv=8) d_ff=512 (per expert)
vocab=49155, MoE 40e top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base].
Note: the assignment's structured field says 40 experts (prose says 32);
we take the structured field (DESIGN §4).
"""
from repro.models.config import MOE, ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family=MOE,
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    head_dim=64,
    num_experts=40,
    experts_per_token=8,
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
