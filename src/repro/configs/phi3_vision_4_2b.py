"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP frontend (stubbed).

Assigned: 32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064
[hf:microsoft/Phi-3-vision-128k-instruct]. The vision encoder + projector is
a stub per the assignment: input_specs() provides patch embeddings (B,T,d).
"""
from repro.models.config import VLM, ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family=VLM,
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    head_dim=96,
    frontend="vision",
    num_frontend_tokens=1024,  # image patch tokens prepended to the prompt
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)
