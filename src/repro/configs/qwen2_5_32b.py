"""qwen2.5-32b [dense] — GQA, QKV bias.

Assigned: 64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064
[hf:Qwen/Qwen2.5-0.5B family card].
"""
from repro.models.config import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family=DENSE,
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=27648,
    vocab_size=152064,
    head_dim=128,
    attn_bias=True,
    rope_theta=1e6,
    source="hf:Qwen/Qwen2.5-0.5B",
)
