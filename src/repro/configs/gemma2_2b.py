"""gemma2-2b [dense] — local/global alternating attention, logit softcaps.

Assigned: 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000
[arXiv:2408.00118]. head_dim=256, sliding window 4096 on even (local)
layers, attn softcap 50, final softcap 30, GeGLU, sandwich norms, embedding
scaling. Long-context mode windows the global layers at 32768 (documented
deviation, DESIGN §4/§8) to make long_500k sub-quadratic.
"""
from repro.models.config import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family=DENSE,
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    d_ff=9216,
    vocab_size=256000,
    head_dim=256,
    sliding_window=4096,
    local_global=True,
    global_window_long=32768,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    use_post_norm=True,
    mlp_act="gelu",
    embed_scale=True,
    tie_embeddings=True,
    source="arXiv:2408.00118",
)
