"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks.

Assigned: 81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000,
ssm_state=64 [arXiv:2411.15242]. Shared attention applied every 6 mamba
layers (weights shared across application points, per the Zamba design).
"""
from repro.models.config import HYBRID, ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family=HYBRID,
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    head_dim=112,             # 3584 / 32
    ssm_state=64,
    ssm_head_dim=64,          # d_inner = 7168 -> 112 mamba heads
    ssm_expand=2,
    ssm_chunk=256,
    shared_attn_every=6,      # 13 applications over 81 layers + 3 trailing
    global_window_long=32768, # long-context mode window for the shared attn
    source="arXiv:2411.15242",
)
