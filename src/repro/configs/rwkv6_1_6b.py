"""rwkv6-1.6b [ssm] — "Finch", attention-free, data-dependent decay.

Assigned: 24L d_model=2048 (attn-free) d_ff=7168 vocab=65536
[arXiv:2404.05892]. 32 heads x head_dim 64. Decode state is O(1) in
sequence length, so the arch runs long_500k.
"""
from repro.models.config import SSM, ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family=SSM,
    num_layers=24,
    d_model=2048,
    num_heads=32,          # = rwkv_heads (d_model / rwkv_head_dim)
    num_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    rwkv=True,
    rwkv_head_dim=64,
    rwkv_lora_dim=64,
    source="arXiv:2404.05892",
)
