"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention.

Assigned: 56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768,
MoE 8e top-2, SWA [arXiv:2401.04088]. SWA window 4096 per the assignment;
the bounded KV cache makes long_500k runnable (DESIGN §4).
"""
from repro.models.config import MOE, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family=MOE,
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    head_dim=128,
    num_experts=8,
    experts_per_token=2,
    sliding_window=4096,
    rope_theta=1e6,
    source="arXiv:2401.04088",
)
