"""qwen2.5-7b — the paper's primary evaluation model (§5.1.2).

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064, QKV bias
[arXiv:2407.10671].
"""
from repro.models.config import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-7b",
    family=DENSE,
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    head_dim=128,
    attn_bias=True,
    rope_theta=1e6,
    source="arXiv:2407.10671 / paper §5.1.2",
)
