"""Architecture config registry. One module per assigned architecture.

``get_config(name)`` returns the exact assigned full-size config;
``get_config(name).reduced()`` is the smoke-test variant.
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

# assigned pool (10) + the paper's own evaluation models (2)
ARCHS = [
    "zamba2_7b",
    "phi3_vision_4_2b",
    "tinyllama_1_1b",
    "whisper_tiny",
    "granite_moe_3b_a800m",
    "mixtral_8x22b",
    "qwen3_8b",
    "qwen2_5_32b",
    "rwkv6_1_6b",
    "gemma2_2b",
    "qwen2_5_7b",   # paper's primary eval model (§5.1.2)
    "qwen2_5_72b",  # paper's large eval model (§5.1.2)
]

_ALIASES = {
    "zamba2-7b": "zamba2_7b",
    "phi-3-vision-4.2b": "phi3_vision_4_2b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "whisper-tiny": "whisper_tiny",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "mixtral-8x22b": "mixtral_8x22b",
    "qwen3-8b": "qwen3_8b",
    "qwen2.5-32b": "qwen2_5_32b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "gemma2-2b": "gemma2_2b",
    "qwen2.5-7b": "qwen2_5_7b",
    "qwen2.5-72b": "qwen2_5_72b",
}

ASSIGNED = list(_ALIASES)[:10]


def get_config(name: str) -> ModelConfig:
    mod = _ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}").CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}
