"""Pure-jnp oracle for paged decode attention: gather pages, mask, softmax."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths, *,
                        logit_softcap: float = 0.0, scale: float | None = None):
    """Same signature/layout as the kernel: q (B, Hkv, G, hd),
    pools (num_pages, page, Hkv, hd), tables (B, P), lengths (B,)."""
    B, Hkv, G, hd = q.shape
    page = k_pages.shape[1]
    P = block_tables.shape[1]
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    # gather this batch's pages -> contiguous (B, P*page, Hkv, hd)
    k = k_pages[block_tables].reshape(B, P * page, Hkv, hd)
    v = v_pages[block_tables].reshape(B, P * page, Hkv, hd)
    s = jnp.einsum("bhgd,bchd->bhgc", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if logit_softcap:
        s = jnp.tanh(s / logit_softcap) * logit_softcap
    valid = jnp.arange(P * page)[None] < lengths[:, None]
    s = jnp.where(valid[:, None, None], s, -1e30)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    o = jnp.einsum("bhgc,bchd->bhgd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
