"""Paged decode attention Pallas TPU kernel.

One new query token per request attends over its KV cache stored in a paged
pool (non-contiguous pages, vLLM-style). The per-request page list
(block table) is a *scalar-prefetch* operand: BlockSpec index_maps read it
to stream exactly the pages belonging to the request from HBM into VMEM —
the TPU-native equivalent of the gather a CUDA paged-attention kernel does
with pointer chasing (DESIGN.md §3, hardware adaptation).

grid = (B, Hkv, pages_per_req), last axis sequential; online-softmax
accumulators persist in VMEM scratch across page iterations. Pages past the
request length are skipped with pl.when (no HBM traffic is saved in
interpret mode, but on TPU the pipeline still fetches — production uses
num_pages-per-request grids; we keep the static bound and mask).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _kernel(lengths_ref, tables_ref,  # scalar prefetch
            q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            page_size: int, pages_per_req: int, logit_softcap: float,
            scale: float):
    b = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lengths_ref[b]
    page_start = pi * page_size

    @pl.when(page_start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # (G, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)        # (page, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (G, page)
        if logit_softcap:
            s = jnp.tanh(s / logit_softcap) * logit_softcap
        pos = page_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new

    @pl.when(pi == pages_per_req - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def paged_attention_pallas(q, k_pages, v_pages, block_tables, lengths, *,
                           logit_softcap: float = 0.0, scale: float,
                           interpret: bool = False):
    """q: (B, Hkv, G, hd) — grouped query heads.
    k_pages/v_pages: (num_pages, page_size, Hkv, hd) paged KV pool.
    block_tables: (B, pages_per_req) int32 page ids (garbage past length ok).
    lengths: (B,) int32 valid tokens per request.
    Returns (B, Hkv, G, hd)."""
    B, Hkv, G, hd = q.shape
    num_pages, page_size = k_pages.shape[0], k_pages.shape[1]
    pages_per_req = block_tables.shape[1]

    kernel = functools.partial(
        _kernel, page_size=page_size, pages_per_req=pages_per_req,
        logit_softcap=logit_softcap, scale=scale)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, pages_per_req),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, pi, lens, tabs: (b, h, 0, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda b, h, pi, lens, tabs: (tabs[b, pi], 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda b, h, pi, lens, tabs: (tabs[b, pi], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, pi, lens, tabs: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, block_tables, q, k_pages, v_pages)
