"""Jit'd public wrapper for paged decode attention.

Model layout in: q (B, Hq, hd) for the single new token per request; the
wrapper regroups GQA heads to (B, Hkv, G, hd) and dispatches to the Pallas
kernel (TPU / interpret) or the jnp oracle (CPU engine fallback).

Dispatch: pass ``backend="auto"|"pallas"|"interpret"|"ref"`` (preferred —
this is what the engine threads through), or the legacy ``use_ref``/
``interpret`` booleans directly.
"""
from __future__ import annotations

import functools

import jax
import numpy as np

from repro.kernels import backend_flags
from repro.kernels.paged_attention.kernel import paged_attention_pallas
from repro.kernels.paged_attention.ref import paged_attention_ref


@functools.partial(jax.jit, static_argnames=("num_kv_heads", "logit_softcap",
                                             "interpret", "use_ref", "backend"))
def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    num_kv_heads: int, logit_softcap: float = 0.0,
                    interpret: bool = False, use_ref: bool = False,
                    backend: str | None = None):
    """q: (B, Hq, hd); pools (num_pages, page, Hkv, hd);
    block_tables (B, P) int32; lengths (B,). Returns (B, Hq, hd)."""
    if backend is not None:
        use_ref, interpret = backend_flags(backend)
    B, Hq, hd = q.shape
    G = Hq // num_kv_heads
    qg = q.reshape(B, num_kv_heads, G, hd)
    scale = 1.0 / np.sqrt(hd)
    fn = paged_attention_ref if use_ref else functools.partial(
        paged_attention_pallas, interpret=interpret)
    o = fn(qg, k_pages, v_pages, block_tables, lengths,
           logit_softcap=logit_softcap, scale=scale)
    return o.reshape(B, Hq, hd)
