# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Attention-backend dispatch shared by the engine and the ops wrappers.

Backends (threaded through ``ServingEngine``/``CoLocatedServer`` and the
kernel wrappers):

* ``"pallas"``    — the Pallas TPU kernels (flash prefill + paged decode).
* ``"interpret"`` — the same Pallas kernels in interpret mode: executes the
  kernel bodies on any backend (CPU parity/debug path).
* ``"ref"``       — the jnp oracles / pure-XLA flash path (CPU fallback).
* ``"auto"``      — ``"pallas"`` when a TPU is attached, else ``"ref"``.
"""
from __future__ import annotations

BACKENDS = ("auto", "pallas", "interpret", "ref")


def resolve_backend(backend: str = "auto") -> str:
    """Collapse ``"auto"`` to a concrete backend for the current platform."""
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
    if backend == "auto":
        import jax
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return backend


def backend_flags(backend: str) -> tuple[bool, bool]:
    """Map a concrete backend to the kernel wrappers' (use_ref, interpret)."""
    backend = resolve_backend(backend)
    return backend == "ref", backend == "interpret"
