"""Jit'd public wrapper for the flash prefill kernel.

Accepts model-layout tensors (B, S, H, hd), pads sequence dims to block
multiples and head_dim to 128 (MXU alignment), and dispatches to the Pallas
kernel (TPU / interpret) or the jnp oracle (CPU fallback for the engine).

Dispatch: pass ``backend="auto"|"pallas"|"interpret"|"ref"`` (preferred),
or the legacy ``use_ref``/``interpret`` booleans directly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import backend_flags
from repro.kernels.flash_prefill.kernel import flash_prefill_pallas
from repro.kernels.flash_prefill.ref import flash_prefill_ref


def _pad_to(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "logit_softcap", "interpret",
                     "block_q", "block_kv", "use_ref", "backend"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    logit_softcap: float = 0.0, kv_lens=None,
                    interpret: bool = False, block_q: int = 128,
                    block_kv: int = 128, use_ref: bool = False,
                    backend: str | None = None):
    """q: (B, Sq, Hq, hd); k, v: (B, Skv, Hkv, hd) -> (B, Sq, Hq, hd).

    kv_lens: (B,) valid kv length per row. The ref path honors it exactly
    (per-row key masking). The Pallas kernel's kv_len is a compile-time
    scalar: per-row lengths cannot be threaded into the BlockSpec grid
    without a scalar-prefetch redesign, so the Pallas path masks only at the
    static ``Skv`` bound — callers with ragged rows must either use the ref
    path or pad rows to a uniform length (the engine prefills one request
    at a time, so its rows are always uniform).
    """
    B, Sq, Hq, hd = q.shape
    Skv = k.shape[1]
    scale = 1.0 / np.sqrt(hd)
    if backend is not None:
        use_ref, interpret = backend_flags(backend)
    qt = _pad_to(_pad_to(q.transpose(0, 2, 1, 3), 2, block_q), 3, 128)
    kt = _pad_to(_pad_to(k.transpose(0, 2, 1, 3), 2, block_kv), 3, 128)
    vt = _pad_to(_pad_to(v.transpose(0, 2, 1, 3), 2, block_kv), 3, 128)
    if use_ref:
        o = flash_prefill_ref(qt, kt, vt, kv_len=Skv, kv_lens=kv_lens,
                              causal=causal, window=window,
                              logit_softcap=logit_softcap, scale=scale)
    else:
        o = flash_prefill_pallas(
            qt, kt, vt, kv_len=Skv, causal=causal, window=window,
            logit_softcap=logit_softcap, scale=scale,
            block_q=block_q, block_kv=block_kv, interpret=interpret)
    return o[:, :, :Sq, :hd].transpose(0, 2, 1, 3)
