"""Jit'd public wrapper for the flash prefill kernel.

Accepts model-layout tensors (B, S, H, hd), pads sequence dims to block
multiples and head_dim to 128 (MXU alignment), and dispatches to the Pallas
kernel (TPU / interpret) or the jnp oracle (CPU fallback for the engine).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_prefill.kernel import flash_prefill_pallas
from repro.kernels.flash_prefill.ref import flash_prefill_ref


def _pad_to(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "logit_softcap", "interpret",
                     "block_q", "block_kv", "use_ref"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    logit_softcap: float = 0.0, kv_lens=None,
                    interpret: bool = False, block_q: int = 128,
                    block_kv: int = 128, use_ref: bool = False):
    """q: (B, Sq, Hq, hd); k, v: (B, Skv, Hkv, hd) -> (B, Sq, Hq, hd).

    kv_lens: (B,) valid kv length per row — the Pallas kernel takes a single
    static kv_len, so variable rows fall back to per-row max (mask exactness
    is preserved through the padding mask only for uniform rows; the engine
    prefills uniform buckets).
    """
    B, Sq, Hq, hd = q.shape
    Skv = k.shape[1]
    scale = 1.0 / np.sqrt(hd)
    qt = _pad_to(_pad_to(q.transpose(0, 2, 1, 3), 2, block_q), 3, 128)
    kt = _pad_to(_pad_to(k.transpose(0, 2, 1, 3), 2, block_kv), 3, 128)
    vt = _pad_to(_pad_to(v.transpose(0, 2, 1, 3), 2, block_kv), 3, 128)
    fn = flash_prefill_ref if use_ref else functools.partial(
        flash_prefill_pallas, block_q=block_q, block_kv=block_kv,
        interpret=interpret)
    o = fn(qt, kt, vt, kv_len=Skv, causal=causal, window=window,
           logit_softcap=logit_softcap, scale=scale)
    return o[:, :, :Sq, :hd].transpose(0, 2, 1, 3)
