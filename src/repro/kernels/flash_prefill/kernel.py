"""Causal flash-attention Pallas TPU kernel (prefill hot path).

Tiling: grid = (batch, q_heads, num_q_blocks, num_kv_blocks) with the last
axis sequential ("arbitrary") so the online-softmax accumulators live in
VMEM scratch across kv iterations. Blocks are (Qb, head_dim) / (Kb, head_dim)
tiles in VMEM; head_dim and block sizes should be multiples of 128 on real
hardware for MXU alignment (the ops wrapper pads).

Causal + sliding-window block skipping: kv blocks entirely outside the
causal/window band are skipped with pl.when — this is the triangular-skip
optimization the pure-XLA path cannot express (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            kv_len: int, q_offset: int, block_q: int, block_kv: int,
            num_kv_blocks: int, causal: bool, window: int,
            logit_softcap: float, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = q_offset + qi * block_q
    k_start = ki * block_kv
    # block-level skip: entirely in the future (causal) or past the window
    run = k_start < kv_len
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
        if window:
            run = jnp.logical_and(
                run, k_start + block_kv - 1 >= q_start - window + 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (Qb, hd)
        k = k_ref[0, 0].astype(jnp.float32)          # (Kb, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (Qb, Kb)
        if logit_softcap:
            s = jnp.tanh(s / logit_softcap) * logit_softcap
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        mask = k_pos < kv_len
        if causal:
            rel = q_pos - k_pos
            mask = jnp.logical_and(mask, rel >= 0)
            if window:
                mask = jnp.logical_and(mask, rel < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_prev * alpha + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_prefill_pallas(q, k, v, *, kv_len: int, q_offset: int = 0,
                         causal: bool = True, window: int = 0,
                         logit_softcap: float = 0.0, scale: float,
                         block_q: int = 128, block_kv: int = 128,
                         interpret: bool = False):
    """q: (B, Hq, Sq, hd); k, v: (B, Hkv, Skv, hd). Sq % block_q == 0,
    Skv % block_kv == 0 (ops wrapper pads). Returns (B, Hq, Sq, hd)."""
    B, Hq, Sq, hd = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    nq = Sq // block_q
    nk = Skv // block_kv

    kernel = functools.partial(
        _kernel, kv_len=kv_len, q_offset=q_offset, block_q=block_q,
        block_kv=block_kv, num_kv_blocks=nk, causal=causal, window=window,
        logit_softcap=logit_softcap, scale=scale)

    return pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_kv, hd), lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, hd), lambda b, h, qi, ki: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
