"""Pure-jnp oracle for the flash prefill kernel (naive full-score attention)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def flash_prefill_ref(q, k, v, *, kv_len: int, q_offset: int = 0,
                      causal: bool = True, window: int = 0,
                      logit_softcap: float = 0.0, scale: float | None = None,
                      kv_lens=None):
    """q: (B, Hq, Sq, hd); k, v: (B, Hkv, Skv, hd). Returns (B, Hq, Sq, hd).

    kv_lens: optional (B,) per-row valid key length — tightens the static
    ``kv_len`` bound row-wise (ragged batches)."""
    B, Hq, Sq, hd = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    qg = q.reshape(B, Hkv, G, Sq, hd).astype(jnp.float32)
    s = jnp.einsum("bkgqd,bkcd->bkgqc", qg, k.astype(jnp.float32)) * scale
    if logit_softcap:
        s = jnp.tanh(s / logit_softcap) * logit_softcap
    q_pos = q_offset + jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Skv)[None, :]
    mask = k_pos < kv_len
    if causal:
        rel = q_pos - k_pos
        mask = mask & (rel >= 0)
        if window:
            mask = mask & (rel < window)
    mask = jnp.broadcast_to(mask[None], (B, Sq, Skv))
    if kv_lens is not None:
        mask = mask & (k_pos[None] < jnp.asarray(kv_lens)[:, None, None])
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    o = jnp.einsum("bkgqc,bkcd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, Sq, hd).astype(q.dtype)
