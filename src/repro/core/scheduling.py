"""OOCO's four scheduling points (paper §3.4, Algorithms 1 & 2).

All functions are pure decisions over request views + the perf model, so the
discrete-event simulator and the real JAX engine execute the *same* logic.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Callable, Sequence

from repro.core.perf_model import PerfModel
from repro.core.request import Kind, Request

LatencyFn = Callable[[Sequence[int]], float]  # context lens -> predicted step s


def _latency(pm: PerfModel, reqs: Sequence[Request]) -> float:
    if not reqs:
        return 0.0
    return pm.decode_estimate([r.context_len for r in reqs]).latency


# ---------------------------------------------------------------------------
# §3.4.4  Mix Decoding Selection (Algorithm 2)
# ---------------------------------------------------------------------------

def mix_decoding_selection(
    online: Sequence[Request],
    offline: Sequence[Request],
    slo: float,
    pm: PerfModel,
    *,
    max_probe: int = 8,
    rng: random.Random | None = None,
    mem_budget_bytes: float | None = None,
) -> list[Request]:
    """Per decode step: all online requests first, then offline requests under
    the TPOT SLO — randomized probing (anti-starvation) followed by
    sort-by-length + binary-search for the largest feasible prefix."""
    import numpy as np

    rng = rng or random.Random(0)
    batch: list[Request] = list(online)
    if not offline:
        return batch

    # incremental latency bookkeeping: L = O_d + gemm(B) + sum(attn terms)
    attn_sum = float(pm.decode_attn_time(
        np.array([r.context_len for r in batch], np.float64)).sum()) if batch else 0.0
    kv_sum = pm.kv_bytes([r.context_len for r in batch]) if batch else 0.0

    def lat_of(B: int, attn: float) -> float:
        return pm.hw.O_d + float(pm._decode_batch_terms(float(B))[2]) + attn

    if lat_of(len(batch), attn_sum) > slo:
        return batch  # online already at/over SLO: best-effort, no offline

    remaining = list(offline)
    probes = min(max_probe, len(remaining))
    for _ in range(probes):
        r = remaining.pop(rng.randrange(len(remaining)))
        a = float(pm.decode_attn_time(np.array([r.context_len], np.float64))[0])
        kv = pm.kv_bytes([r.context_len])
        if lat_of(len(batch) + 1, attn_sum + a) <= slo and (
                mem_budget_bytes is None or kv_sum + kv <= mem_budget_bytes):
            batch.append(r)
            attn_sum += a
            kv_sum += kv
        # else: discard for this step (Alg. 2 line 7)

    if remaining and lat_of(len(batch), attn_sum) < slo:
        remaining.sort(key=lambda r: r.context_len)
        ctx = np.array([r.context_len for r in remaining], np.float64)
        curve = pm.decode_latency_curve(
            np.array([r.context_len for r in batch], np.float64), ctx)
        ok = curve <= slo
        if mem_budget_bytes is not None:
            per_kv = pm.kv_bytes_per_request(ctx)
            kv_curve = kv_sum + np.concatenate([[0.0], np.cumsum(per_kv)])
            ok &= kv_curve <= mem_budget_bytes
        # largest feasible prefix (curve is monotone in k)
        k = int(np.searchsorted(~ok[1:], True)) if len(ok) > 1 else 0
        batch.extend(remaining[:k])
    return batch


# ---------------------------------------------------------------------------
# Token-budget scheduling for fused mixed prefill/decode rounds
# ---------------------------------------------------------------------------

@dataclass
class MixedPlan:
    """One engine round under the token-budget scheduler: the decode batch
    plus (optionally) a prefill chunk fused into the same dispatch, and/or
    a multi-step horizon. ``horizon > 1`` with ``prefill`` set means a
    fused *mixed-horizon* round: one dispatch runs ``horizon`` decode
    iterations while landing the chunk as ``horizon`` sub-chunk slices
    (``split_chunk``), so the round's token budget covers
    ``decode x horizon + chunk_tokens`` total tokens."""
    decode: list[Request]
    prefill: Request | None = None
    chunk_tokens: int = 0      # prompt tokens of `prefill` to run this round
    horizon: int = 1           # fused decode iterations this round

    @property
    def total_tokens(self) -> int:
        return len(self.decode) * self.horizon + self.chunk_tokens


def split_chunk(chunk_tokens: int, steps: int) -> list[int]:
    """Split a prefill chunk into per-iteration sub-chunk sizes for a
    mixed-horizon dispatch: ``steps`` slices, each >= 1 token, differing by
    at most one token, summing exactly to ``chunk_tokens``. The larger
    slices come first so the final slice is never the odd one out."""
    steps = max(min(int(steps), int(chunk_tokens)), 1)
    base, rem = divmod(int(chunk_tokens), steps)
    return [base + 1 if i < rem else base for i in range(steps)]


def token_budget_schedule(
    online: Sequence[Request],
    offline: Sequence[Request],
    prefill: Request | None,
    prefill_remaining: int,
    pm: PerfModel,
    *,
    slo: float | None = None,
    budget_tokens: int | None = None,
    relaxed_cap: int | None = None,
    mem_budget_bytes: float | None = None,
    rng: random.Random | None = None,
    bucket: int = 8,
    decode_override: list[Request] | None = None,
    horizon: int = 1,
) -> MixedPlan:
    """Sarathi-style token-budget plan replacing the prefill-then-decode
    serialization: decode tokens ride first (one token each — they carry the
    latency SLO), and the leftover roofline budget becomes the prefill
    chunk, so every fused round sits near the compute/memory ridge instead
    of alternating between a memory-bound decode step and an
    over-long compute-bound prefill.

    ``slo`` set (latency-strict rounds): the decode batch comes from
    §3.4.4 mix-decoding selection and the chunk shrinks until the
    perf-model-predicted fused-step latency stays within the SLO (possibly
    to zero — decode SLO always wins). ``slo`` None (latency-relaxed
    rounds): decode is capped by ``relaxed_cap`` and the chunk floor is one
    bucket, so a resident decode batch can never starve prefill progress.
    ``budget_tokens`` overrides the roofline suggestion (``--chunk-tokens
    N``); ``decode_override`` lets a caller keep its own decode-batch
    policy (the runtime's baselines) while the budget sizes the chunk.
    ``horizon`` is the caller's multi-step decode-horizon allowance. On a
    chunkless round the plan carries it directly (token budget =
    decode-batch x horizon). When a chunk rides a latency-relaxed round
    the plan now keeps ``horizon > 1`` too — the round becomes one fused
    mixed-horizon dispatch whose budget is split into ``horizon``
    sub-chunks — clamped to ``chunk // bucket`` so every non-final
    sub-chunk is at least one bucket (~one page) of prefill. Strict
    rounds keep single-step fused semantics (``horizon == 1``)."""
    if decode_override is not None:
        decode = list(decode_override)
    elif slo is not None:
        decode = mix_decoding_selection(
            online, offline, slo, pm, rng=rng,
            mem_budget_bytes=mem_budget_bytes)
    else:
        decode = list(online) + list(offline)[:relaxed_cap]
    if prefill is None or prefill_remaining <= 0:
        return MixedPlan(decode, horizon=max(int(horizon), 1))
    dec_ctx = [r.context_len for r in decode]
    netted = budget_tokens is None
    if netted:
        # roofline ridge budget, already net of the decode batch's GEMM
        # share (the SLO cap is applied once, exactly, below)
        budget_tokens = pm.suggest_chunk_tokens(dec_ctx, bucket=bucket)
    if slo is not None:
        # latency-bound round: decode tokens spend the same budget (they
        # share the step's GEMMs), so the chunk gets the leftover
        chunk = max(budget_tokens if netted else budget_tokens - len(decode),
                    0)
    elif prefill.kind is Kind.ONLINE:
        # the chunk budget bounds how much OFFLINE prefill work can delay
        # latency-critical work per round (§3.4.1); an online prefill IS
        # the latency-critical work — chunking it only defers its own TTFT
        chunk = prefill_remaining
    else:
        # latency-relaxed round: the budget is a roofline floor, not a
        # latency cap — shrinking the chunk below it for resident decodes
        # only multiplies rounds (and their static overheads)
        chunk = max(budget_tokens, bucket)
    chunk = min(chunk, prefill_remaining)
    if slo is not None and chunk > 0:
        # largest bucket-multiple chunk whose fused step meets the SLO
        lo, hi, best = 1, -(-chunk // bucket), 0
        while lo <= hi:
            mid = (lo + hi) // 2
            t = min(mid * bucket, chunk)
            est = pm.mixed_estimate(
                t, prefill.prefill_tokens_done + t, dec_ctx,
                cached_tokens=getattr(prefill, "cached_tokens", 0))
            if est.latency <= slo:
                best, lo = t, mid + 1
            else:
                hi = mid - 1
        chunk = best
    if chunk <= 0:
        return MixedPlan(decode, horizon=max(int(horizon), 1))
    horizon = max(int(horizon), 1)
    if slo is not None:
        # latency-strict chunked round: one uninterruptible dispatch per
        # horizon would stretch the preemption boundary past the SLO math
        # above, which sized the chunk for a single fused step
        horizon = 1
    elif horizon > 1:
        # every non-final sub-chunk must carry at least one bucket (~one
        # page) of prefill, or splitting only multiplies scatter overhead
        horizon = max(1, min(horizon, int(chunk) // max(int(bucket), 1)))
    return MixedPlan(decode, prefill, int(chunk), horizon)


def decode_horizon_steps(
    batch: Sequence[Request],
    pm: PerfModel,
    *,
    requested: int | str | None,
    strict: bool = False,
    queued_online: bool = False,
    preempt_latency: float | None = None,
    max_horizon: int = 16,
) -> int:
    """§3.4.1-aware multi-step decode-horizon choice for one engine round.

    Latency-relaxed all-offline rounds amortize the per-dispatch overhead
    over roofline-chosen horizons (``requested="auto"`` routes through
    ``PerfModel.suggest_decode_horizon`` under the ``preempt_latency``
    bound — a horizon is one uninterruptible dispatch, so a queued online
    request waits at most one horizon). Latency-strict rounds, rounds
    decoding ANY online request, and rounds with an online request already
    queued clamp to K=1 so fast preemption and pull migration keep today's
    boundaries. K is also capped by the longest remaining output in the
    batch — steps past every row's ``max_new_tokens`` are pure waste."""
    if requested in (None, 0, 1, "0", "1") or not batch:
        return 1
    if strict or queued_online:
        return 1
    if any(r.kind is Kind.ONLINE for r in batch):
        return 1
    cap = min(int(max_horizon), max(r.remaining for r in batch))
    if cap <= 1:
        return 1
    if requested == "auto":
        k = pm.suggest_decode_horizon(
            [r.context_len for r in batch],
            preempt_latency=preempt_latency, max_horizon=cap)
    else:
        k = int(requested)
    return max(1, min(k, cap))


# ---------------------------------------------------------------------------
# §3.4.3  Offline Request Migration (Algorithm 1)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LengthPreference:
    """Pull-model preference a latency-strict node sends to relaxed nodes."""
    target_len: int      # preferred context length of requests to pull
    mode: str            # "longest" | "bounded" | "shortest"
    count: int = 1       # how many requests it is willing to absorb


def migration_decision(
    batch: Sequence[Request],
    all_node_requests_included: bool,
    slo: float,
    pm: PerfModel,
    *,
    mem_budget_bytes: float,
    slo_margin: float = 0.85,
    max_probe_len: int = 1 << 17,
) -> LengthPreference | None:
    """Algorithm 1: a latency-strict node with SLO headroom computes the
    request-length preference that best fills its dominant bottleneck."""
    import numpy as np

    ctx = np.array([r.context_len for r in batch], np.float64)
    B = len(batch)
    # O(1)-per-probe decomposition: L(B ∪ extras) = O_d + gemm(B+k) + Σ attn
    attn_base = float(pm.decode_attn_time(ctx).sum()) if B else 0.0
    kv_base = pm.kv_bytes(ctx) if B else 0.0

    def lat_with(l: int, k: int) -> float:
        a = float(pm.decode_attn_time(np.array([l], np.float64))[0])
        return (pm.hw.O_d + float(pm._decode_batch_terms(float(B + k))[2])
                + attn_base + k * a)

    def mem_ok(l: int, k: int) -> bool:
        per = float(pm.kv_bytes_per_request(np.array([l], np.float64))[0])
        return kv_base + k * per <= mem_budget_bytes

    lat = pm.hw.O_d + (float(pm._decode_batch_terms(float(B))[2]) + attn_base
                       if B else 0.0)
    if not (lat < slo * slo_margin and all_node_requests_included):
        return None  # no migration (Alg. 1 line 16)

    bs_sat = pm.compute_saturated_batch(int(ctx.mean()) if B else 512)

    def max_len_under(k: int) -> int:
        lo, hi, best = 1, max_probe_len, 0
        while lo <= hi:
            mid = (lo + hi) // 2
            if lat_with(mid, k) <= slo and mem_ok(mid, k):
                best, lo = mid, mid + 1
            else:
                hi = mid - 1
        return best

    if B >= bs_sat:
        # compute-saturated: batch growth buys nothing — fill *memory
        # capacity* with the longest request that fits SLO + memory
        best = max_len_under(1)
        if best:
            return LengthPreference(best, "longest")
        return None

    # not saturated: try to reach saturation within the SLO
    need = bs_sat - B
    if lat_with(1, need) <= slo and mem_ok(1, need):
        best = max_len_under(need)
        if best:
            return LengthPreference(best, "bounded", count=need)
    # cannot reach saturation: maximize batch size with the shortest requests
    return LengthPreference(1, "shortest", count=max(need, 1))


def select_for_migration(
    candidates: Sequence[Request],
    pref: LengthPreference,
) -> list[Request]:
    """Latency-relaxed side of the pull: pick the decoding offline requests
    closest to the preference (paper: 'most closed to Pref')."""
    if not candidates:
        return []
    ranked = sorted(candidates, key=lambda r: abs(r.context_len - pref.target_len))
    if pref.mode == "longest":
        # respect the upper bound strictly: never exceed target
        ranked = [r for r in ranked if r.context_len <= pref.target_len] or ranked[:1]
    return ranked[: pref.count]


# ---------------------------------------------------------------------------
# §3.4.1  Online preemption — eviction victim selection on strict nodes
# ---------------------------------------------------------------------------

def select_eviction_victims(
    offline_running: Sequence[Request],
    needed_tokens: int,
    bottleneck: str,
    shared_tokens: "dict[int, int] | None" = None,
) -> list[Request]:
    """Free >= needed_tokens of KV space for an incoming online request.

    compute-bound node: evict FEW LONG requests (preserves decode batch
    size, which is what compute efficiency depends on); otherwise evict
    SHORT ones (cheap recompute). Paper §3.4.1.

    ``shared_tokens`` maps rid -> tokens living on refcount>1 pages (the
    prefix cache). Evicting such a request frees only its UNSHARED tail —
    the shared pages stay resident for siblings — so victims are ranked by
    the space they actually release, unshared requests are preferred, and a
    victim that frees nothing is never picked while an alternative exists.

    Online requests are never eviction victims, even if the caller passes a
    mixed resident list (§3.4.1 evicts offline work only)."""
    candidates = [r for r in offline_running if r.kind is not Kind.ONLINE]
    shared = shared_tokens or {}

    def releasable(r: Request) -> int:
        return max(r.context_len - shared.get(r.rid, 0), 0)

    key = ((lambda r: (-releasable(r), -r.context_len))
           if bottleneck == "compute"
           else (lambda r: (shared.get(r.rid, 0) > 0, r.context_len)))
    ranked = sorted(candidates, key=key)
    victims, freed = [], 0
    for r in ranked:
        if freed >= needed_tokens:
            break
        if releasable(r) == 0 and shared:
            continue   # frees nothing: shared pages survive the eviction
        victims.append(r)
        freed += releasable(r) if shared else r.context_len
    if freed >= needed_tokens:
        return victims
    # cannot satisfy the demand: fall back to every candidate that frees
    # anything at all (legacy behavior when no sharing info is supplied)
    return [r for r in candidates if not shared or releasable(r) > 0] \
        or candidates


# ---------------------------------------------------------------------------
# Graceful degradation — overload admission control (offline sheds first)
# ---------------------------------------------------------------------------

def admission_decision(
    *,
    queued_online: int,
    strict_pressure: float,
    offline_backlog: int,
    free_page_frac: float = 1.0,
    max_backlog: int | None = None,
    pressure_high: float = 0.95,
    queue_high: int = 8,
    free_low: float = 0.02,
) -> str:
    """Overload gate for admitting NEW offline work: ``"admit"`` |
    ``"defer"`` | ``"shed"``.

    The degradation order is the point (HyGen/ConServe: SLO guarantees must
    hold under adverse conditions): when the cluster is overloaded — a deep
    online queue, the strict pool's pressure EMA pinned near the SLO with
    online work still waiting, or the relaxed pool's free pages nearly
    exhausted — fresh offline prefills stop being admitted (*defer*: they
    stay queued, costing nothing), so online SLO attainment decays last.
    Only when the offline backlog itself exceeds ``max_backlog`` (bounded
    queue — the operator's memory guard) is offline work *shed*, and sheds
    are always surfaced in ``summary()['shed_requests']``, never silent.
    Online work is never deferred or shed here. ``max_backlog=None``
    disables shedding entirely (defer-only degradation, the default)."""
    overloaded = (queued_online >= queue_high
                  or (queued_online > 0 and strict_pressure >= pressure_high)
                  or free_page_frac <= free_low)
    if not overloaded:
        return "admit"
    if max_backlog is not None and offline_backlog > max_backlog:
        return "shed"
    return "defer"


# ---------------------------------------------------------------------------
# Live-serving deadlines (gateway / PR 9)
# ---------------------------------------------------------------------------

def deadline_state(req: Request, now: float) -> str:
    """Classify a live request against its client deadlines: ``"ok"`` |
    ``"ttft_blown"`` | ``"total_blown"``.

    Pure decision function (the runtime loop enforces the abort): a request
    whose TTFT deadline passed while it was still waiting for its first
    token, or whose total deadline passed before it finished, is not worth
    another FLOP — prefilling or decoding it only steals budget from
    requests that can still meet their SLOs. Deadlines are seconds relative
    to arrival; ``None`` means unbounded."""
    elapsed = now - req.arrival
    if (req.total_deadline is not None and not req.done
            and elapsed > req.total_deadline):
        return "total_blown"
    if (req.ttft_deadline is not None and req.first_token_time is None
            and elapsed > req.ttft_deadline):
        return "ttft_blown"
    return "ok"


# ---------------------------------------------------------------------------
# §3.4.2  Offline Request Gating (cost model)
# ---------------------------------------------------------------------------

def gating_decision(
    candidate: Request,
    current_offline_batch: Sequence[Request],
    pm: PerfModel,
    *,
    evict_probability: float,
    horizon_seconds: float,
    mem_budget_bytes: float,
    cached_tokens: int = 0,
) -> bool:
    """Prefill a new offline request on a relaxed node only if the expected
    throughput gain from the larger decode batch exceeds the expected
    recompute cost from potential eviction.

    ``cached_tokens`` is the candidate's prefix-cache hit length: cached
    tokens cost a page-table update instead of prefill FLOPs and add no new
    KV bytes, so a warm candidate is both cheaper to admit and cheaper to
    lose — the gate sees its true residual work."""
    cached = max(0, min(int(cached_tokens), candidate.prompt_len - 1))
    ctx = [r.context_len for r in current_offline_batch]
    # shared pages are already resident: only the suffix adds KV pressure
    if pm.kv_bytes(ctx + [candidate.prompt_len - cached]) > mem_budget_bytes:
        return False
    if not ctx:
        return True  # idle node: always worth prefilling
    lat_now = pm.decode_estimate(ctx).latency
    lat_new = pm.decode_estimate(ctx + [candidate.prompt_len]).latency
    rate_now = len(ctx) / lat_now
    rate_new = (len(ctx) + 1) / lat_new
    gain_tokens = max(rate_new - rate_now, 0.0) * horizon_seconds
    prefill_s = pm.prefill_estimate([candidate.prompt_len],
                                    [cached]).latency
    cost_tokens = evict_probability * prefill_s * rate_new
    return gain_tokens > cost_tokens
