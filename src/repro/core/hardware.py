"""Hardware calibrations for the perf model (paper Table 4 parameters).

The paper profiles these on Ascend 910c; we provide:
  - TPU_V5E: analytic calibration from the assignment's roofline constants
    (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI) with standard
    achievable-fraction deratings (MXU GEMM ~85 %, attention ~60/40 %).
    Used by the cluster simulator and the roofline analysis.
  - ASCEND_910C: the paper's platform, reconstructed from public numbers
    (A100-class: ~400 TFLOP/s fp16 per chip in the 910c dual-die package ->
    ~each die ≈ A100) — used to sanity-check Figure 3 shapes.
  - cpu_measured(): fitted from timed engine runs in this container
    (benchmarks/bench_perfmodel_accuracy.py writes the fit).
"""
from __future__ import annotations

from repro.core.perf_model import HardwareParams

TPU_V5E = HardwareParams(
    name="tpu_v5e",
    F_g=197e12 * 0.85,
    F_ap=197e12 * 0.60,
    F_ad=197e12 * 0.40,
    M_g=819e9 * 0.80,
    M_a=819e9 * 0.70,
    O_p=8e-3,
    O_d=4e-3,
    B_c=50e9 * 0.80,          # one ICI link direction, 80 % efficiency
    hbm_capacity=16e9,
    peak_flops=197e12,
    peak_hbm_bw=819e9,
)

ASCEND_910C = HardwareParams(
    name="ascend_910c",
    F_g=320e12 * 0.75,        # per chip (dual-die), bf16, A100-SXM class
    F_ap=320e12 * 0.55,
    F_ad=320e12 * 0.35,
    M_g=1.6e12 * 0.75,
    M_a=1.6e12 * 0.65,
    O_p=10e-3,                # paper: xLLM prefill runtime overhead
    O_d=4e-3,
    B_c=100e9,                # RDMA KV-transfer effective bandwidth
    hbm_capacity=64e9,
    peak_flops=320e12,
    peak_hbm_bw=1.6e12,
)


def cpu_measured(F: float = 50e9, M: float = 10e9, O_p: float = 30e-3,
                 O_d: float = 8e-3) -> HardwareParams:
    """Container-CPU calibration; defaults are rough, the accuracy benchmark
    fits them from measured prefill/decode timings."""
    return HardwareParams(
        name="cpu", F_g=F, F_ap=F * 0.7, F_ad=F * 0.5, M_g=M, M_a=M,
        O_p=O_p, O_d=O_d, B_c=1e9, hbm_capacity=8e9, peak_flops=F,
        peak_hbm_bw=M)
