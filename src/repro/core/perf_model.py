"""Roofline-based LLM inference performance model (paper §3.3, Tables 2–4, Eq. 1).

An operator-level behavioural simulator: for a given Prefill or Decode batch
it enumerates the model's GEMM / attention / SSM / communication operators,
assigns each theoretical FLOPs and memory traffic (Table 3), and predicts
latency as  max(FLOPs / F_a, Bytes / M_a)  per operator (Eq. 1), summed plus
a static per-iteration overhead (O_p / O_d) and communication time
(bytes / B_c).

Two calibrations ship (repro/core/hardware.py): a TPU-v5e analytic set used
by the cluster simulator, and a CPU-measured set fitted from timed JAX
engine runs, used to validate the paper's ≈5 % error claim
(benchmarks/bench_perfmodel_accuracy.py).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Iterable, Sequence

import numpy as np

from repro.models.config import AUDIO, HYBRID, MOE, SSM, VLM, ModelConfig


@dataclass(frozen=True)
class HardwareParams:
    """Table 4 symbols. FLOP/s and bytes/s are *achievable*, not peak."""

    name: str
    F_g: float    # achievable FLOP/s, GEMM
    F_ap: float   # achievable FLOP/s, prefill attention
    F_ad: float   # achievable FLOP/s, decode attention
    M_g: float    # achievable bytes/s, GEMM
    M_a: float    # achievable bytes/s, attention
    O_p: float    # static overhead per prefill iteration (s)
    O_d: float    # static overhead per decode iteration (s)
    B_c: float    # effective interconnect bytes/s (KV migration / collectives)
    hbm_capacity: float  # bytes per chip
    peak_flops: float    # theoretical peak (roofline ceiling, reporting only)
    peak_hbm_bw: float


@dataclass
class OpCost:
    name: str
    flops: float
    bytes: float
    kind: str  # gemm | attn_p | attn_d | ssm | comm | other

    def latency(self, hw: HardwareParams) -> float:
        if self.kind == "comm":
            return self.bytes / hw.B_c
        f = {"gemm": hw.F_g, "attn_p": hw.F_ap, "attn_d": hw.F_ad}.get(self.kind, hw.F_g)
        m = hw.M_a if self.kind in ("attn_p", "attn_d") else hw.M_g
        return max(self.flops / f, self.bytes / m)  # Eq. 1


@dataclass
class StepEstimate:
    """Prediction for one Prefill or Decode iteration."""

    latency: float
    flops: float
    bytes: float
    compute_time: float       # sum of per-op flops/F terms
    memory_time: float        # sum of per-op bytes/M terms
    comm_time: float
    overhead: float
    kv_bytes: float           # decode-cache bytes touched (capacity pressure)
    bottleneck: str           # "compute" | "memory" | "balanced" | "overhead"
    ops: list[OpCost] = field(default_factory=list)

    @property
    def compute_util(self) -> float:
        return self.compute_time / self.latency if self.latency else 0.0

    @property
    def memory_util(self) -> float:
        return self.memory_time / self.latency if self.latency else 0.0


class PerfModel:
    """Operator-level simulator for one model on one instance type.

    tp: tensor-parallel degree of the instance (the paper deploys 72B with
    TP=4); FLOPs/bytes are divided across chips and TP collectives added.
    """

    def __init__(self, cfg: ModelConfig, hw: HardwareParams, *, tp: int = 1,
                 d: int = 2):
        self.cfg = cfg
        self.hw = hw
        self.tp = tp
        self.d = d  # bytes per value (Table 2)

    # ------------------------------------------------------------------
    # Table 3 operator models
    # ------------------------------------------------------------------
    def _gemm(self, name: str, N: int, Din: int, Dout: int) -> OpCost:
        d = self.d
        flops = 2.0 * N * Din * Dout
        bytes_ = d * (N * Din + Din * Dout + N * Dout)
        return OpCost(name, flops / self.tp, bytes_ / self.tp, "gemm")

    def _attention(self, name: str, Dh: int, Sq: int, Skv: int, Hq: int,
                   Hkv: int, decode: bool) -> OpCost:
        # Table 3: FLOPs = 4 Dh Sq Skv (two GEMMs over the score matrix);
        # Memory = 2 d (Sq Dh + Skv Dh Hq/Hkv scaled to kv heads) — fused
        # kernel, intermediate scores stay on-chip (Flash semantics).
        d = self.d
        dh_total = Hq * (Dh // max(Hq, 1)) if False else Dh  # Dh = total hidden
        flops = 4.0 * dh_total * Sq * Skv
        bytes_ = 2.0 * d * (Sq * dh_total + Skv * dh_total * Hkv / Hq)
        kind = "attn_d" if decode else "attn_p"
        return OpCost(name, flops / self.tp, bytes_ / self.tp, kind)

    def _comm(self, name: str, bytes_: float) -> OpCost:
        return OpCost(name, 0.0, bytes_, "comm")

    # ------------------------------------------------------------------
    # per-layer operator inventories
    # ------------------------------------------------------------------
    def _layer_ops(self, n_tokens: int, attn_sq: Sequence[int],
                   attn_skv: Sequence[int], decode: bool) -> list[OpCost]:
        """Operators of one transformer layer for a batch with ``n_tokens``
        total tokens; attention is per-request (Sq_i, Skv_i) pairs."""
        cfg = self.cfg
        d = cfg.d_model
        hd = cfg.head_dim_
        Hq, Hkv = cfg.num_heads, cfg.num_kv_heads
        ops: list[OpCost] = []
        if cfg.family == SSM:
            return self._rwkv_layer_ops(n_tokens, decode)
        ops.append(self._gemm("qkv", n_tokens, d, (Hq + 2 * Hkv) * hd))
        Dh = Hq * hd
        for sq, skv in zip(attn_sq, attn_skv):
            ops.append(self._attention("attn", Dh, sq, skv, Hq, Hkv, decode))
        ops.append(self._gemm("o_proj", n_tokens, Hq * hd, d))
        if cfg.is_moe:
            ops.append(self._gemm("router", n_tokens, d, cfg.num_experts))
            # active-expert GEMMs: k experts per token; weights read for
            # min(E, tokens*k) experts (decode batches touch every expert)
            eff_tokens = n_tokens * cfg.experts_per_token
            n_active_exp = min(cfg.num_experts, eff_tokens)
            dff = cfg.d_ff
            flops = 3 * 2.0 * eff_tokens * d * dff
            w_bytes = self.d * 3 * n_active_exp * d * dff
            a_bytes = self.d * (2 * eff_tokens * d + eff_tokens * dff * 3)
            ops.append(OpCost("moe_ffn", flops / self.tp,
                              (w_bytes + a_bytes) / self.tp, "gemm"))
        else:
            n_mats = 2 if cfg.mlp_act == "gelu_mlp" else 3
            for i in range(n_mats - 1):
                ops.append(self._gemm(f"mlp_up{i}", n_tokens, d, cfg.d_ff))
            ops.append(self._gemm("mlp_down", n_tokens, cfg.d_ff, d))
        if self.tp > 1:
            # 2 all-reduces per layer (after attn, after mlp), ring: 2(tp-1)/tp
            ar = 2 * (self.tp - 1) / self.tp * n_tokens * d * self.d
            ops.append(self._comm("tp_allreduce", 2 * ar))
        return ops

    def _mamba_layer_ops(self, n_tokens: int, decode: bool) -> list[OpCost]:
        cfg = self.cfg
        d, di, ns, nh = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_nheads
        ops = [self._gemm("mamba_in", n_tokens, d, 2 * di + 2 * ns + nh)]
        # SSD scan: per token, state update nh*hd*ns MACs x2 + output x2
        hd = cfg.ssm_head_dim
        flops = 6.0 * n_tokens * nh * hd * ns
        state_bytes = 4.0 * nh * hd * ns  # f32 state read+write per step
        n_steps = n_tokens if decode else max(1, n_tokens // cfg.ssm_chunk)
        bytes_ = self.d * 2 * n_tokens * di + state_bytes * 2 * n_steps
        ops.append(OpCost("ssd_scan", flops / self.tp, bytes_ / self.tp,
                          "attn_d" if decode else "attn_p"))
        ops.append(self._gemm("mamba_out", n_tokens, di, d))
        return ops

    def _rwkv_layer_ops(self, n_tokens: int, decode: bool) -> list[OpCost]:
        cfg = self.cfg
        d, H, hd = cfg.d_model, cfg.rwkv_heads, cfg.rwkv_head_dim
        ops = [self._gemm(n, n_tokens, d, H * hd)
               for n in ("tm_r", "tm_k", "tm_v", "tm_g")]
        ops.append(self._gemm("tm_out", n_tokens, H * hd, d))
        ops.append(self._gemm("w_lora", n_tokens, d, cfg.rwkv_lora_dim))
        # wkv recurrence: per token per head 4*hd*hd MACs; f32 state traffic
        flops = 8.0 * n_tokens * H * hd * hd
        bytes_ = self.d * 2 * n_tokens * d + 8.0 * H * hd * hd * n_tokens * (
            1.0 if decode else 1.0 / max(cfg.ssm_chunk, 1))
        ops.append(OpCost("wkv", flops / self.tp, bytes_ / self.tp,
                          "attn_d" if decode else "attn_p"))
        ops.append(self._gemm("cm_k", n_tokens, d, cfg.d_ff))
        ops.append(self._gemm("cm_v", n_tokens, cfg.d_ff, d))
        ops.append(self._gemm("cm_r", n_tokens, d, d))
        return ops

    def _all_layers(self, n_tokens: int, attn_sq, attn_skv, decode: bool) -> list[OpCost]:
        cfg = self.cfg
        ops: list[OpCost] = []
        if cfg.family == HYBRID:
            per_mamba = self._mamba_layer_ops(n_tokens, decode)
            n_attn = cfg.num_layers // cfg.shared_attn_every
            per_attn = self._layer_ops(n_tokens, attn_sq, attn_skv, decode)
            ops += [dataclasses.replace(o) for _ in range(cfg.num_layers) for o in per_mamba]
            ops += [dataclasses.replace(o) for _ in range(n_attn) for o in per_attn]
        elif cfg.family == AUDIO:
            dec = self._layer_ops(n_tokens, attn_sq, attn_skv, decode)
            # cross attention ≈ one more attention + 2 projections per layer
            ops += [dataclasses.replace(o) for _ in range(cfg.num_layers) for o in dec]
            cross = [self._attention("cross", cfg.num_heads * cfg.head_dim_,
                                     sq, cfg.num_frontend_tokens, cfg.num_heads,
                                     cfg.num_kv_heads, decode) for sq in attn_sq]
            ops += [dataclasses.replace(o) for _ in range(cfg.num_layers) for o in cross]
        else:
            per = self._layer_ops(n_tokens, attn_sq, attn_skv, decode)
            ops += [dataclasses.replace(o) for _ in range(cfg.num_layers) for o in per]
        # logits are computed for one position per request (last token /
        # current decode token)
        ops.append(self._gemm("lm_head", len(attn_sq), cfg.d_model, cfg.vocab_size))
        return ops

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    def _page_table_op(self, cached_tokens: int) -> OpCost:
        """A prefix-cache hit converts prefill work into a page-table
        update: one page-id write (~4 B/token amortized) plus a refcount
        bump per claimed page — pure bookkeeping bandwidth, zero FLOPs."""
        return OpCost("page_table", 0.0, 4.0 * cached_tokens, "other")

    def prefill_estimate(self, seq_lens: Sequence[int],
                         cached_tokens: Sequence[int] | None = None
                         ) -> StepEstimate:
        """One prefill iteration over requests with the given prompt
        lengths. ``cached_tokens[i]`` prompt tokens of request *i* are
        served from the prefix cache (page-table update, no compute); only
        the uncached suffix runs through the stack, though each suffix
        query still attends over the full cached context."""
        seq_lens = list(seq_lens)
        if cached_tokens is None:
            cached = [0] * len(seq_lens)
        else:
            # a hit never covers the whole prompt (last token is always
            # computed so the first output token exists)
            cached = [min(max(int(c), 0), s - 1)
                      for c, s in zip(cached_tokens, seq_lens)]
        new = [s - c for s, c in zip(seq_lens, cached)]
        n_tokens = int(sum(new))
        # causal attention: a suffix query attends over the cached prefix
        # plus, on average, half of the new span
        ops = self._all_layers(n_tokens, new,
                               [max(c + n // 2, 1)
                                for c, n in zip(cached, new)], decode=False)
        tot_cached = sum(cached)
        if tot_cached:
            ops.append(self._page_table_op(tot_cached))
        # only the suffix KV is newly written; cached pages are resident
        return self._sum(ops, self.hw.O_p, kv_bytes=self.kv_bytes(new))

    def mixed_estimate(self, chunk_tokens: int, chunk_ctx: int,
                       decode_ctx: Sequence[int] = (), *,
                       cached_tokens: int = 0) -> StepEstimate:
        """One **fused mixed step**: a prefill chunk of ``chunk_tokens``
        (query positions ``[chunk_ctx - chunk_tokens, chunk_ctx)`` attending
        to the ``chunk_ctx`` tokens landed so far) executed in the same
        dispatch as a decode batch over ``decode_ctx``.

        Ops run back-to-back on the same instance, so per-op latencies sum,
        but the static dispatch overhead is paid **once** — the structural
        win of fusing over the serialized prefill-then-decode rounds
        (Sarathi-style chunked prefill, paper §3.4.1 boundary granularity).

        ``cached_tokens`` of ``chunk_ctx`` came from the prefix cache: they
        were never computed here, so the step only adds KV capacity for the
        residual context and pays a page-table bookkeeping op for the
        claim. The chunk's attention span is unchanged — suffix queries
        attend over cached keys just the same.
        """
        chunk_tokens = int(chunk_tokens)
        cached_tokens = max(0, min(int(cached_tokens),
                                   int(chunk_ctx) - chunk_tokens))
        decode_ctx = np.asarray(list(decode_ctx), np.float64)
        overhead = max(self.hw.O_p if chunk_tokens else 0.0,
                       self.hw.O_d if decode_ctx.size else 0.0)
        lat, fl, by, comp, mem, comm, kvb = overhead, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0
        if chunk_tokens:
            # chunk queries average Skv = ctx_before + chunk/2 keys (causal)
            skv = max(int(chunk_ctx) - chunk_tokens // 2, 1)
            ops = self._all_layers(chunk_tokens, [chunk_tokens], [skv],
                                   decode=False)
            if cached_tokens:
                ops.append(self._page_table_op(cached_tokens))
            p = self._sum(ops, 0.0, kv_bytes=0.0)
            lat += p.latency
            fl += p.flops
            by += p.bytes
            comp += p.compute_time
            mem += p.memory_time
            comm += p.comm_time
            kvb += self.kv_bytes([max(int(chunk_ctx) - cached_tokens, 1)])
        if decode_ctx.size:
            d = self._fast_decode(decode_ctx)
            lat += d.latency - self.hw.O_d
            fl += d.flops
            by += d.bytes
            comp += d.compute_time
            mem += d.memory_time
            kvb += d.kv_bytes
        work = lat - overhead
        if work <= 0 or overhead > work:
            bn = "overhead"
        elif comp > 1.3 * mem:
            bn = "compute"
        elif mem > 1.3 * comp:
            bn = "memory"
        else:
            bn = "balanced"
        return StepEstimate(latency=lat, flops=fl, bytes=by, compute_time=comp,
                            memory_time=mem, comm_time=comm, overhead=overhead,
                            kv_bytes=kvb, bottleneck=bn)

    def prefill_saturation_tokens(self, max_t: int = 8192) -> int:
        """Roofline ridge point for prefill: the smallest token count whose
        step is compute-bound (GEMM flops/F_g >= bytes/M_g) with the static
        overhead an amortized minority (O_p <= 10% of step latency). Below
        this, a prefill chunk wastes bandwidth/dispatch; above it, extra
        chunk length only adds latency without improving utilization —
        which is exactly the chunk-size sweet spot chunked-prefill
        schedulers aim for. Memoized (schedulers call this every round)."""
        cached = getattr(self, "_prefill_sat_cache", None)
        if cached is not None and cached[0] == max_t:
            return cached[1]

        def saturated(T: int) -> bool:
            ops = self._layer_ops(T, [T], [max(T // 2, 1)], decode=False)
            gf = sum(o.flops for o in ops if o.kind == "gemm")
            gb = sum(o.bytes for o in ops if o.kind == "gemm")
            lat = self.prefill_estimate([T]).latency
            return (gf / self.hw.F_g >= gb / self.hw.M_g
                    and self.hw.O_p <= 0.1 * lat)

        lo, hi = 1, max_t
        if not saturated(hi):
            self._prefill_sat_cache = (max_t, max_t)
            return max_t
        while lo < hi:
            mid = (lo + hi) // 2
            if saturated(mid):
                hi = mid
            else:
                lo = mid + 1
        self._prefill_sat_cache = (max_t, lo)
        return lo

    def suggest_chunk_tokens(self, decode_ctx: Sequence[int] = (), *,
                             slo: float | None = None, chunk_ctx: int = 0,
                             bucket: int = 8, max_chunk: int = 4096,
                             cached_tokens: int = 0) -> int:
        """Pick the prefill-chunk token budget for a fused mixed step from
        the roofline ridge: start at ``prefill_saturation_tokens`` (decode
        rows share the GEMM, so their batch size is subtracted), round up to
        a bucket multiple, then — if an SLO bounds this step (latency-strict
        rounds) — shrink to the largest bucket multiple whose
        ``mixed_estimate`` stays within it. Returns 0 when even one bucket
        of prefill would break the SLO."""
        decode_ctx = list(decode_ctx)
        ridge = self.prefill_saturation_tokens(max_chunk)
        budget = max(ridge - len(decode_ctx), bucket)
        budget = min(-(-budget // bucket) * bucket, max_chunk)
        if slo is None:
            return budget
        lo, hi, best = 1, budget // bucket, 0
        while lo <= hi:
            mid = (lo + hi) // 2
            t = mid * bucket
            # a warm-started chunk's context is at least cached + chunk
            if self.mixed_estimate(t, max(chunk_ctx, cached_tokens + t),
                                   decode_ctx,
                                   cached_tokens=cached_tokens).latency <= slo:
                best, lo = t, mid + 1
            else:
                hi = mid - 1
        return best

    def horizon_estimate(self, decode_ctx: Sequence[int],
                         steps: int) -> StepEstimate:
        """One **K-step fused decode horizon**: ``steps`` consecutive decode
        iterations for a batch with the given context lengths executed as a
        single dispatch, so the static per-iteration overhead ``O_d`` is
        paid ONCE per horizon instead of once per token — the structural
        win of multi-step decode, exactly like ``mixed_estimate`` pays one
        overhead for the fused chunk+decode round.

        Per-step attention grows by one token per request inside the
        horizon; the sum over steps equals ``steps`` x the estimate at the
        midpoint context ``c + (K-1)/2`` (exact while attention cost is
        linear in context — i.e. away from a sliding-window cap)."""
        ctx = np.asarray(list(decode_ctx), np.float64)
        steps = max(int(steps), 1)
        hw = self.hw
        if ctx.size == 0:
            return StepEstimate(hw.O_d, 0, 0, 0, 0, 0, hw.O_d, 0, "overhead")
        if steps == 1:
            return self._fast_decode(ctx)
        gf, gb, gl, gc, gm = self._decode_batch_terms(float(len(ctx)))
        mid = ctx + (steps - 1) / 2.0
        af, ab, ac, am = self._decode_attn_fb(mid)
        al = self.decode_attn_time(mid).sum()
        lat = float(hw.O_d + steps * (gl + al))
        fl, by = float(steps * (gf + af)), float(steps * (gb + ab))
        comp, mem = float(steps * (gc + ac)), float(steps * (gm + am))
        work = lat - hw.O_d
        if hw.O_d > work:
            bn = "overhead"
        elif comp > 1.3 * mem:
            bn = "compute"
        elif mem > 1.3 * comp:
            bn = "memory"
        else:
            bn = "balanced"
        return StepEstimate(latency=lat, flops=fl, bytes=by, compute_time=comp,
                            memory_time=mem, comm_time=0.0, overhead=hw.O_d,
                            kv_bytes=self.kv_bytes(ctx + steps - 1),
                            bottleneck=bn)

    def suggest_decode_horizon(self, decode_ctx: Sequence[int], *,
                               slo: float | None = None,
                               preempt_latency: float | None = None,
                               dispatch_overhead: float | None = None,
                               overhead_frac: float = 0.02,
                               max_horizon: int = 16) -> int:
        """Roofline-chosen multi-step decode horizon K.

        Amortization: the smallest K that makes the per-dispatch overhead
        (``O_d``, or the larger measured ``dispatch_overhead`` when the
        caller has timed the real host gap between sync and next dispatch)
        an ``overhead_frac`` minority of the horizon's latency — beyond
        that, longer horizons buy nothing on the roofline and only coarsen
        scheduling granularity.

        Bounds: a horizon is ONE uninterruptible dispatch whose tokens
        arrive in a burst at the end, so its total latency must stay under
        the TPOT ``slo`` (latency-strict rounds) and under the §3.4.1
        ``preempt_latency`` bound (a queued online request waits at most
        one horizon before preemption can fire). Returns 1 when even a
        single step sits at a bound — never worse than today's behavior."""
        ctx = np.asarray(list(decode_ctx), np.float64)
        if ctx.size == 0:
            return 1
        ov = float(self.hw.O_d if dispatch_overhead is None
                   else max(dispatch_overhead, self.hw.O_d))
        w = max(self._fast_decode(ctx).latency - self.hw.O_d, 1e-12)
        k = int(np.ceil(ov * (1.0 - overhead_frac) / (overhead_frac * w)))
        k = min(max(k, 1), max(int(max_horizon), 1))
        bound = min((b for b in (slo, preempt_latency) if b is not None),
                    default=None)
        if bound is not None:
            while k > 1 and (self.horizon_estimate(ctx, k).latency
                             - self.hw.O_d + ov) > bound:
                k -= 1
        return k

    def mixed_horizon_estimate(self, chunk_tokens: int, chunk_ctx: int,
                               decode_ctx: Sequence[int] = (),
                               steps: int = 1, *,
                               cached_tokens: int = 0) -> StepEstimate:
        """One **fused mixed-horizon dispatch**: ``steps`` decode iterations
        for the batch over ``decode_ctx`` run in a single ``lax.scan``
        while the prefill chunk of ``chunk_tokens`` lands as ``steps``
        sub-chunk slices (``scheduling.split_chunk``), the final slice
        ending at ``chunk_ctx``. One static dispatch overhead per horizon.

        Chunk work is summed per sub-chunk (K slices stream the weights K
        times — the real cost of splitting, so the estimate is honest about
        when fusing does NOT pay); decode attention is evaluated at the
        midpoint context ``c + (K-1)/2`` exactly like
        ``horizon_estimate``."""
        chunk_tokens = int(chunk_tokens)
        steps = max(int(steps), 1)
        if chunk_tokens <= 0:
            return self.horizon_estimate(decode_ctx, steps)
        if steps == 1:
            return self.mixed_estimate(chunk_tokens, chunk_ctx, decode_ctx,
                                       cached_tokens=cached_tokens)
        steps = min(steps, chunk_tokens)
        cached_tokens = max(0, min(int(cached_tokens),
                                   int(chunk_ctx) - chunk_tokens))
        ctx = np.asarray(list(decode_ctx), np.float64)
        hw = self.hw
        overhead = max(hw.O_p, hw.O_d if ctx.size else 0.0)
        lat, fl, by, comp, mem, comm, kvb = (overhead, 0.0, 0.0, 0.0, 0.0,
                                             0.0, 0.0)
        # chunk side: sum the per-sub-chunk estimates (same int arithmetic
        # as mixed_estimate applied slice by slice)
        done = int(chunk_ctx) - chunk_tokens
        base, rem = divmod(chunk_tokens, steps)
        pos = done
        for i in range(steps):
            s = base + 1 if i < rem else base
            skv = max(pos + s - s // 2, 1)
            ops = self._all_layers(s, [s], [skv], decode=False)
            p = self._sum(ops, 0.0, kv_bytes=0.0)
            lat += p.latency
            fl += p.flops
            by += p.bytes
            comp += p.compute_time
            mem += p.memory_time
            comm += p.comm_time
            pos += s
        if cached_tokens:
            p = self._sum([self._page_table_op(cached_tokens)], 0.0,
                          kv_bytes=0.0)
            lat += p.latency
            fl += p.flops
            by += p.bytes
            comp += p.compute_time
            mem += p.memory_time
        kvb += self.kv_bytes([max(int(chunk_ctx) - cached_tokens, 1)])
        if ctx.size:
            gf, gb, gl, gc, gm = self._decode_batch_terms(float(len(ctx)))
            mid = ctx + (steps - 1) / 2.0
            af, ab, ac, am = self._decode_attn_fb(mid)
            al = self.decode_attn_time(mid).sum()
            lat += float(steps * (gl + al))
            fl += float(steps * (gf + af))
            by += float(steps * (gb + ab))
            comp += float(steps * (gc + ac))
            mem += float(steps * (gm + am))
            kvb += self.kv_bytes(ctx + steps - 1)
        work = lat - overhead
        if work <= 0 or overhead > work:
            bn = "overhead"
        elif comp > 1.3 * mem:
            bn = "compute"
        elif mem > 1.3 * comp:
            bn = "memory"
        else:
            bn = "balanced"
        return StepEstimate(latency=lat, flops=fl, bytes=by, compute_time=comp,
                            memory_time=mem, comm_time=comm, overhead=overhead,
                            kv_bytes=kvb, bottleneck=bn)

    def suggest_mixed_horizon(self, chunk_tokens: int, chunk_ctx: int,
                              decode_ctx: Sequence[int] = (), *,
                              slo: float | None = None,
                              preempt_latency: float | None = None,
                              queued_online: bool = False,
                              dispatch_overhead: float | None = None,
                              overhead_frac: float = 0.02,
                              max_horizon: int = 16) -> int:
        """Horizon K for a fused mixed round (chunk + decode in one scan).

        Amortization targets the DECODE side (the chunk's weight streaming
        is paid per sub-chunk either way, so splitting a chunk with no
        decode batch riding is strictly worse — returns 1). Fusing is NOT
        free for the chunk: every scan iteration re-streams the weights,
        so a K-horizon pays K weight streams to land the SAME chunk one
        round used to land in one stream — K only wins when the amortized
        dispatch overhead plus the extra decode tokens beat that cost. K
        is therefore chosen to maximize the round's MODELED token
        throughput, ``(chunk + K * batch) / latency(K)``, over candidate
        horizons up to the decode-amortization bound (overhead-dominated
        hardware pushes K up; streaming-dominated hardware keeps K at 1).
        The §3.4.1 bound applies to the whole dispatch: a horizon is one
        uninterruptible unit, so chunk-boundary preemption becomes
        horizon-boundary preemption and the horizon's end-to-end latency
        must fit under ``min(slo, preempt_latency)``. With online arrivals
        already queued (``queued_online``) the remaining preemption budget
        is half — K shrinks rather than pinning to 1, because the chunk
        still has to land either way."""
        chunk_tokens = int(chunk_tokens)
        ctx = list(decode_ctx)
        if chunk_tokens <= 0:
            return self.suggest_decode_horizon(
                ctx, slo=slo, preempt_latency=preempt_latency,
                dispatch_overhead=dispatch_overhead,
                overhead_frac=overhead_frac, max_horizon=max_horizon)
        if not ctx:
            return 1
        arr = np.asarray(ctx, np.float64)
        ov = float(self.hw.O_d if dispatch_overhead is None
                   else max(dispatch_overhead, self.hw.O_d))
        w = max(self._fast_decode(arr).latency - self.hw.O_d, 1e-12)
        k = int(np.ceil(ov * (1.0 - overhead_frac) / (overhead_frac * w)))
        k = min(max(k, 1), max(int(max_horizon), 1), chunk_tokens)
        if k > 1:
            # modeled-throughput argmax over candidate horizons (powers of
            # two up to the amortization bound): tokens landed per modeled
            # second, counting the chunk once and one decode token per
            # resident per iteration
            cands = sorted({1, k} | {c for c in (2, 4, 8, 16, 32)
                                     if c < k})
            extra = ov - max(self.hw.O_p, self.hw.O_d)

            def tput(c):
                est = self.mixed_horizon_estimate(
                    chunk_tokens, chunk_ctx, ctx, c)
                return (chunk_tokens + c * len(ctx)) / (
                    est.latency + max(extra, 0.0))
            k = max(cands, key=tput)
        bound = min((b for b in (slo, preempt_latency) if b is not None),
                    default=None)
        if bound is not None:
            if queued_online:
                bound = bound / 2.0
            model_ov = max(self.hw.O_p, self.hw.O_d)
            while k > 1 and (self.mixed_horizon_estimate(
                    chunk_tokens, chunk_ctx, ctx, k).latency
                    - model_ov + max(ov, model_ov)) > bound:
                k -= 1
        return k

    def decode_estimate(self, context_lens: Sequence[int],
                        detail: bool = False) -> StepEstimate:
        """One decode step for a batch whose requests have the given context
        (KV) lengths. n_tokens = batch size (one new token each).

        The default path is numpy-vectorized (the schedulers/simulator call
        this thousands of times per run); detail=True builds the per-op list.
        """
        if not detail:
            return self._fast_decode(np.asarray(context_lens, np.float64))
        B = len(context_lens)
        lens = [self._effective_ctx(c) for c in context_lens]
        ops = self._all_layers(B, [1] * B, lens, decode=True)
        return self._sum(ops, self.hw.O_d, kv_bytes=self.kv_bytes(context_lens))

    # ------------------------------------------------------------------
    # vectorized decode estimate (identical math, no per-op objects)
    #
    # Split into a batch-size-dependent part (GEMMs / SSM scan / comm) and a
    # per-request attention part, so the schedulers can evaluate latency
    # curves over candidate batches in O(1) per candidate (Alg. 1/2 run this
    # every decode step — see decode_latency_curve).
    # ------------------------------------------------------------------
    def _decode_batch_terms(self, n):
        """Batch-size-dependent terms. n: scalar or array of batch sizes.
        Returns (flops, bytes, latency, comp_time, mem_time) arrays."""
        cfg, hw, d = self.cfg, self.hw, self.d
        n = np.asarray(n, np.float64)

        def gemm(N, Din, Dout, count=1.0):
            f = 2.0 * N * Din * Dout * count / self.tp
            b = d * (N * Din + Din * Dout + N * Dout) * count / self.tp
            return f, b, np.maximum(f / hw.F_g, b / hw.M_g), f / hw.F_g, b / hw.M_g

        terms = []
        dm, hd = cfg.d_model, cfg.head_dim_
        Hq, Hkv = cfg.num_heads, cfg.num_kv_heads
        if cfg.family == SSM:
            H, rhd = cfg.rwkv_heads, cfg.rwkv_head_dim
            L = cfg.num_layers
            for (Din, Dout, cnt) in [(dm, H * rhd, 4 * L), (H * rhd, dm, L),
                                     (dm, cfg.rwkv_lora_dim, L), (dm, cfg.d_ff, L),
                                     (cfg.d_ff, dm, L), (dm, dm, L)]:
                terms.append(gemm(n, Din, Dout, cnt))
            f = 8.0 * n * H * rhd * rhd * L / self.tp
            b = (d * 2 * n * dm + 8.0 * H * rhd * rhd * n) * L / self.tp
            terms.append((f, b, np.maximum(f / hw.F_ad, b / hw.M_a),
                          f / hw.F_ad, b / hw.M_a))
        else:
            if cfg.family == HYBRID:
                L_attn = cfg.num_layers // cfg.shared_attn_every
                L_m = cfg.num_layers
                di, ns, nh = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_nheads
                terms.append(gemm(n, dm, 2 * di + 2 * ns + nh, L_m))
                terms.append(gemm(n, di, dm, L_m))
                sf = 6.0 * n * nh * cfg.ssm_head_dim * ns * L_m / self.tp
                sb = (d * 2 * n * di + 8.0 * nh * cfg.ssm_head_dim * ns * n) * L_m / self.tp
                terms.append((sf, sb, np.maximum(sf / hw.F_ad, sb / hw.M_a),
                              sf / hw.F_ad, sb / hw.M_a))
            else:
                L_attn = cfg.num_layers
            terms.append(gemm(n, dm, (Hq + 2 * Hkv) * hd, L_attn))
            terms.append(gemm(n, Hq * hd, dm, L_attn))
            if cfg.is_moe:
                terms.append(gemm(n, dm, cfg.num_experts, L_attn))
                eff_tok = n * cfg.experts_per_token
                n_act = np.minimum(cfg.num_experts, eff_tok)
                f = 3 * 2.0 * eff_tok * dm * cfg.d_ff * L_attn / self.tp
                b = (d * 3 * n_act * dm * cfg.d_ff
                     + d * (2 * eff_tok * dm + 3 * eff_tok * cfg.d_ff)) * L_attn / self.tp
                terms.append((f, b, np.maximum(f / hw.F_g, b / hw.M_g),
                              f / hw.F_g, b / hw.M_g))
            elif cfg.family == AUDIO:
                terms.append(gemm(n, dm, cfg.d_ff, L_attn))
                terms.append(gemm(n, cfg.d_ff, dm, L_attn))
            else:
                n_up = 1 if cfg.mlp_act == "gelu_mlp" else 2
                terms.append(gemm(n, dm, cfg.d_ff, n_up * L_attn))
                terms.append(gemm(n, cfg.d_ff, dm, L_attn))
            if self.tp > 1:
                ar = 4 * (self.tp - 1) / self.tp * n * dm * d * L_attn
                terms.append((np.zeros_like(n), ar, ar / hw.B_c,
                              np.zeros_like(n), np.zeros_like(n)))
        terms.append(gemm(n, dm, cfg.vocab_size))
        return tuple(sum(t[i] for t in terms) for i in range(5))

    def decode_attn_time(self, ctx: np.ndarray) -> np.ndarray:
        """Per-request attention latency contribution (seconds each)."""
        cfg, hw, d = self.cfg, self.hw, self.d
        ctx = np.asarray(ctx, np.float64)
        if cfg.family == SSM:
            return np.zeros_like(ctx)
        eff = np.minimum(ctx, cfg.sliding_window) if cfg.sliding_window else ctx
        if cfg.local_global:
            eff = (np.minimum(ctx, cfg.sliding_window) + ctx) / 2.0
        Hq, Hkv = cfg.num_heads, cfg.num_kv_heads
        Dh = Hq * cfg.head_dim_
        L_attn = (cfg.num_layers // cfg.shared_attn_every
                  if cfg.family == HYBRID else cfg.num_layers)
        f = 4.0 * Dh * eff / self.tp
        b = 2.0 * d * (Dh + eff * Dh * Hkv / Hq) / self.tp
        lat = np.maximum(f / hw.F_ad, b / hw.M_a) * L_attn
        if cfg.family == AUDIO:  # cross attention over the encoder output
            cf = 4.0 * Dh * cfg.num_frontend_tokens / self.tp
            cb = 2.0 * d * (Dh + cfg.num_frontend_tokens * Dh * Hkv / Hq) / self.tp
            lat = lat + max(cf / hw.F_ad, cb / hw.M_a) * cfg.num_layers
        return lat

    def _decode_attn_fb(self, ctx: np.ndarray):
        """(flops, bytes, comp_time, mem_time) totals for the attention part."""
        cfg, hw, d = self.cfg, self.hw, self.d
        ctx = np.asarray(ctx, np.float64)
        if cfg.family == SSM:
            return 0.0, 0.0, 0.0, 0.0
        eff = np.minimum(ctx, cfg.sliding_window) if cfg.sliding_window else ctx
        if cfg.local_global:
            eff = (np.minimum(ctx, cfg.sliding_window) + ctx) / 2.0
        Hq, Hkv = cfg.num_heads, cfg.num_kv_heads
        Dh = Hq * cfg.head_dim_
        L_attn = (cfg.num_layers // cfg.shared_attn_every
                  if cfg.family == HYBRID else cfg.num_layers)
        f = (4.0 * Dh * eff / self.tp).sum() * L_attn
        b = (2.0 * d * (Dh + eff * Dh * Hkv / Hq) / self.tp).sum() * L_attn
        if cfg.family == AUDIO:
            B = len(ctx)
            f += 4.0 * Dh * cfg.num_frontend_tokens * B * cfg.num_layers / self.tp
            b += (2.0 * d * (Dh + cfg.num_frontend_tokens * Dh * Hkv / Hq)
                  * B * cfg.num_layers / self.tp)
        return f, b, f / hw.F_ad, b / hw.M_a

    def decode_latency_curve(self, base_ctx, extras_sorted) -> np.ndarray:
        """Latency of base batch plus the first k extras, for k = 0..K.
        O(B + K) total — used by Alg. 2's largest-prefix search."""
        base_ctx = np.asarray(base_ctx, np.float64)
        extras = np.asarray(extras_sorted, np.float64)
        B0, K = len(base_ctx), len(extras)
        ns = B0 + np.arange(K + 1, dtype=np.float64)
        gl = self._decode_batch_terms(ns)[2]
        a0 = self.decode_attn_time(base_ctx).sum() if B0 else 0.0
        pref = np.concatenate([[0.0], np.cumsum(self.decode_attn_time(extras))])
        return self.hw.O_d + gl + a0 + pref

    def _fast_decode(self, ctx: np.ndarray) -> StepEstimate:
        hw = self.hw
        B = len(ctx)
        if B == 0:
            return StepEstimate(hw.O_d, 0, 0, 0, 0, 0, hw.O_d, 0, "overhead")
        gf, gb, gl, gc, gm = self._decode_batch_terms(float(B))
        af, ab, ac, am = self._decode_attn_fb(ctx)
        al = self.decode_attn_time(ctx).sum()
        fl, by = float(gf + af), float(gb + ab)
        lat = float(hw.O_d + gl + al)
        comp, mem = float(gc + ac), float(gm + am)
        work = lat - hw.O_d
        if hw.O_d > work:
            bn = "overhead"
        elif comp > 1.3 * mem:
            bn = "compute"
        elif mem > 1.3 * comp:
            bn = "memory"
        else:
            bn = "balanced"
        return StepEstimate(latency=lat, flops=fl, bytes=by, compute_time=comp,
                            memory_time=mem, comm_time=0.0, overhead=hw.O_d,
                            kv_bytes=self.kv_bytes(ctx), bottleneck=bn)

    def _effective_ctx(self, c: int) -> float:
        w = self.cfg.sliding_window
        if self.cfg.local_global:
            # half the layers are windowed — approximate per-layer mix
            return (min(c, w) + c) / 2.0 if w else c
        return min(c, w) if w else c

    def kv_bytes(self, context_lens) -> float:
        """Decode-state bytes for these requests (capacity + migration cost)."""
        cfg = self.cfg
        ctx = np.asarray(list(context_lens) if not isinstance(
            context_lens, np.ndarray) else context_lens, np.float64)
        if ctx.size == 0:
            return 0.0
        eff = np.minimum(ctx, cfg.sliding_window) if cfg.sliding_window else ctx
        if cfg.local_global:
            eff = (np.minimum(ctx, cfg.sliding_window) + ctx) / 2.0
        per_tok = self.kv_bytes_per_token()
        fixed = self.state_bytes_fixed()
        return float(per_tok * eff.sum() + fixed * ctx.size) / self.tp

    def kv_bytes_per_request(self, ctx: np.ndarray) -> np.ndarray:
        cfg = self.cfg
        ctx = np.asarray(ctx, np.float64)
        eff = np.minimum(ctx, cfg.sliding_window) if cfg.sliding_window else ctx
        if cfg.local_global:
            eff = (np.minimum(ctx, cfg.sliding_window) + ctx) / 2.0
        return (self.kv_bytes_per_token() * eff + self.state_bytes_fixed()) / self.tp

    def kv_bytes_per_token(self) -> float:
        cfg = self.cfg
        if cfg.family == SSM:
            return 0.0
        n_attn_layers = (cfg.num_layers // cfg.shared_attn_every
                         if cfg.family == HYBRID else cfg.num_layers)
        return 2.0 * self.d * cfg.num_kv_heads * cfg.head_dim_ * n_attn_layers

    def state_bytes_fixed(self) -> float:
        """Per-request O(1) state (SSM/conv/rwkv) independent of length."""
        cfg = self.cfg
        if cfg.family == SSM:
            H, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
            return (4.0 * H * hd * hd + 2 * self.d * cfg.d_model) * cfg.num_layers
        if cfg.family == HYBRID:
            nh, hd, ns = cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state
            conv = self.d * (cfg.ssm_conv - 1) * (cfg.ssm_d_inner + 2 * ns)
            return (4.0 * nh * hd * ns + conv) * cfg.num_layers
        return 0.0

    def weight_bytes(self) -> float:
        return self.d * self.cfg.num_params() / self.tp

    def migration_seconds(self, context_len: int) -> float:
        """KV/state transfer time relaxed->strict over the interconnect."""
        b = self.kv_bytes([context_len])
        return b / self.hw.B_c

    def _sum(self, ops: list[OpCost], overhead: float, kv_bytes: float) -> StepEstimate:
        lat = overhead
        comp = mem = comm = fl = by = 0.0
        for o in ops:
            lat += o.latency(self.hw)
            fl += o.flops
            by += o.bytes
            if o.kind == "comm":
                comm += o.bytes / self.hw.B_c
            else:
                f = {"gemm": self.hw.F_g, "attn_p": self.hw.F_ap,
                     "attn_d": self.hw.F_ad}.get(o.kind, self.hw.F_g)
                m = self.hw.M_a if o.kind.startswith("attn") else self.hw.M_g
                comp += o.flops / f
                mem += o.bytes / m
        work = lat - overhead
        if work <= 0:
            bn = "overhead"
        elif overhead > work:
            bn = "overhead"
        elif comp > 1.3 * mem:
            bn = "compute"
        elif mem > 1.3 * comp:
            bn = "memory"
        else:
            bn = "balanced"
        return StepEstimate(latency=lat, flops=fl, bytes=by, compute_time=comp,
                            memory_time=mem, comm_time=comm, overhead=overhead,
                            kv_bytes=kv_bytes, bottleneck=bn, ops=ops)

    # ------------------------------------------------------------------
    def compute_saturated_batch(self, ctx_len: int = 512, max_b: int = 4096) -> int:
        """bs_sat (Alg. 1): smallest decode batch where GEMM time is
        compute-bound (flops/F_g >= bytes/M_g). Binary search; memoized on a
        power-of-two ctx bucket (schedulers call this every decode step)."""
        key = (max(ctx_len, 1).bit_length(), max_b)
        cache = getattr(self, "_bs_sat_cache", None)
        if cache is None:
            cache = self._bs_sat_cache = {}
        if key in cache:
            return cache[key]
        cache[key] = v = self._compute_saturated_batch(ctx_len, max_b)
        return v

    def _compute_saturated_batch(self, ctx_len: int, max_b: int) -> int:
        lo, hi = 1, max_b
        if not self._gemm_compute_bound(hi, ctx_len):
            return max_b
        while lo < hi:
            mid = (lo + hi) // 2
            if self._gemm_compute_bound(mid, ctx_len):
                hi = mid
            else:
                lo = mid + 1
        return lo

    def _gemm_compute_bound(self, B: int, ctx: int) -> bool:
        est = self.decode_estimate([ctx] * B, detail=True)
        gemm_f = sum(o.flops for o in est.ops if o.kind == "gemm")
        gemm_b = sum(o.bytes for o in est.ops if o.kind == "gemm")
        return gemm_f / self.hw.F_g >= gemm_b / self.hw.M_g
