"""Request abstraction shared by the schedulers, the cluster simulator and
the serving engine."""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from enum import Enum


class Kind(str, Enum):
    ONLINE = "online"
    OFFLINE = "offline"


class Phase(str, Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    MIGRATING = "migrating"
    DECODING = "decoding"
    EVICTED = "evicted"     # must re-prefill (recompute) before decoding again
    FINISHED = "finished"
    CANCELLED = "cancelled"  # terminal: client abort or deadline exceeded


_ids = itertools.count()


@dataclass
class Request:
    kind: Kind
    arrival: float
    prompt_len: int
    output_len: int                  # ground-truth tokens to generate
    rid: int = field(default_factory=lambda: next(_ids))

    # --- client-visible lifecycle limits (seconds relative to arrival) ---
    ttft_deadline: float | None = None   # abort if no first token by then
    total_deadline: float | None = None  # abort if not finished by then

    # --- runtime state ---
    phase: Phase = Phase.QUEUED
    generated: int = 0
    prefill_layers_done: int = 0     # layer-level interruption progress
    prefill_tokens_done: int = 0     # chunked-prefill progress (tokens landed)
    cached_tokens: int = 0           # leading tokens claimed from the prefix
                                     # cache (counted in prefill_tokens_done)
    location: str | None = None      # instance id currently holding state
    prefill_end: float | None = None
    first_token_time: float | None = None
    finish_time: float | None = None
    decode_time_sum: float = 0.0     # accumulated decode step latencies
    evictions: int = 0
    recompute_tokens: int = 0        # wasted prefill tokens from evictions
    cancel_reason: str | None = None  # "client" | "deadline" once CANCELLED

    @property
    def context_len(self) -> int:
        return self.prompt_len + self.generated

    @property
    def remaining(self) -> int:
        return self.output_len - self.generated

    @property
    def done(self) -> bool:
        return self.generated >= self.output_len

    # --- SLO accounting (paper §2.1: TTFT + TPOT per request) ---
    def ttft(self) -> float | None:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival

    def avg_tpot(self) -> float | None:
        if self.generated <= 1:
            return None
        return self.decode_time_sum / max(self.generated - 1, 1)

    def violates(self, ttft_slo: float, tpot_slo: float, now: float | None = None) -> bool:
        t = self.ttft()
        if t is None:
            # still waiting: violated once the deadline has passed
            return now is not None and (now - self.arrival) > ttft_slo
        if t > ttft_slo:
            return True
        tp = self.avg_tpot()
        return tp is not None and tp > tpot_slo
