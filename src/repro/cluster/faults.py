"""Deterministic fault injection for the pool runtime (chaos replay).

Production brings failures the paper's evaluation never sees: engine
processes crash, KV transfers drop or corrupt on the wire, a dispatch
wedges, an allocator leaks pages. This module turns those into *seeded,
replayable* events so every chaos run is bit-reproducible under the
``VirtualClock`` — the same ``FaultPlan`` + ``chaos_seed`` produce the same
crash rounds, the same flaky-transfer outcomes, the same jittered backoff
delays, and therefore the same metrics JSON and token streams (asserted in
``tests/test_fault_tolerance.py`` and the ``chaos-replay`` CI job).

Fault types (``FaultEvent.kind``):

* ``crash`` — the named engine dies at virtual time ``at``: device KV and
  host bookkeeping are lost; the runtime recovers every in-flight request
  through the recompute path (see ``PoolRuntime._crash_engine``).
* ``stuck`` — the named engine's next dispatch at/after ``at`` hangs; the
  runtime's watchdog aborts it after ``watchdog_mult`` x the
  roofline-predicted round latency (charged to the clock, no tokens
  emitted).
* ``page_leak`` — ``pages`` pool pages of the named engine vanish from the
  free list at ``at`` (allocator leak / fragmentation analogue) and return
  after ``duration`` seconds (0 = never).
* ``migration_fail`` — the next ``count`` KV-transfer attempts at/after
  ``at`` fail in-flight (dropped on the wire, detected before import).
* ``migration_corrupt`` — like ``migration_fail`` but the payload arrives
  bit-flipped; the destination's transfer checksum catches it
  (``kv_cache.verify_transfer``) and the runtime retries.
* ``migration_flaky`` — every transfer attempt fails independently with
  probability ``p``, drawn from the injector's seeded RNG (deterministic
  given the seed and the replay's attempt order).

Plans parse from JSON (a list of event objects, inline or a file path) or
from a compact CLI spec::

    crash:relaxed1@3.0,stuck:relaxed0@2.0,page_leak:strict0@1.5:pages=64:duration=2.0,migration_flaky:p=0.25
"""
from __future__ import annotations

import json
import os
import random
from dataclasses import dataclass, field

FAULT_KINDS = ("crash", "stuck", "page_leak", "migration_fail",
               "migration_corrupt", "migration_flaky")


@dataclass
class FaultEvent:
    kind: str
    engine: str | None = None   # crash/stuck/page_leak target
    at: float = 0.0             # clock time the event arms
    count: int = 1              # migration_fail/corrupt: attempts to fail
    pages: int = 0              # page_leak: pages withheld
    duration: float = 0.0       # page_leak: seconds until restored (0=never)
    p: float = 0.0              # migration_flaky: per-attempt failure prob

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if self.kind in ("crash", "stuck", "page_leak") and not self.engine:
            raise ValueError(f"fault {self.kind!r} needs an engine name")
        if self.kind == "page_leak" and self.pages <= 0:
            raise ValueError("page_leak needs pages > 0")
        if self.kind == "migration_flaky" and not 0.0 < self.p <= 1.0:
            raise ValueError("migration_flaky needs 0 < p <= 1")
        if self.at < 0 or self.duration < 0 or self.count < 1:
            raise ValueError(f"bad fault timing fields in {self}")


@dataclass
class FaultPlan:
    events: list[FaultEvent] = field(default_factory=list)

    @classmethod
    def parse(cls, spec: "str | FaultPlan | list | None") -> "FaultPlan | None":
        """Accept a FaultPlan, a list of event dicts, a JSON string, a JSON
        file path, or the compact comma spec. None/'' -> None (no faults)."""
        if spec is None or spec == "":
            return None
        if isinstance(spec, FaultPlan):
            return spec
        if isinstance(spec, list):
            return cls([e if isinstance(e, FaultEvent) else FaultEvent(**e)
                        for e in spec])
        spec = spec.strip()
        if os.path.isfile(spec):
            with open(spec) as f:
                spec = f.read().strip()
        if spec.startswith("["):
            return cls.parse(json.loads(spec))
        return cls([_parse_compact_event(tok)
                    for tok in spec.split(",") if tok.strip()])


def _parse_compact_event(tok: str) -> FaultEvent:
    """``kind[:engine][@t][:k=v...]`` — '@t' may ride any ':'-field."""
    fields = tok.strip().split(":")
    kw: dict = {}

    def take_at(s: str) -> str:
        if "@" in s:
            s, at = s.rsplit("@", 1)
            kw["at"] = float(at)
        return s

    kind = take_at(fields[0])
    for f in fields[1:]:
        f = take_at(f)
        if not f:
            continue
        if "=" in f:
            k, v = f.split("=", 1)
            if k not in ("engine", "kind"):
                kw[k] = float(v) if k in ("at", "duration", "p") else int(v)
            else:
                kw[k] = v
        else:
            kw["engine"] = f
    return FaultEvent(kind=kind, **kw)


class FaultInjector:
    """Stateful, seeded dispatcher of a ``FaultPlan`` over a replay.

    All randomness (flaky-transfer coin flips, backoff jitter) comes from
    one ``random.Random(seed)`` consumed in the deterministic round-loop
    order, so a chaos replay is exactly as reproducible as a clean one.
    The runtime polls the ``*_due`` hooks at round boundaries and the
    ``transfer_*`` hooks per migration attempt; the injector only *decides*
    — the runtime executes (crashes engines, withholds pages, retries)."""

    def __init__(self, plan: FaultPlan, seed: int = 0):
        self.plan = plan
        self.seed = seed
        self.rng = random.Random(seed)
        self.faults_injected = 0
        self._fired: set[int] = set()       # indices of one-shot events done
        self._fail_budget: list = []        # armed migration_fail/corrupt evs
        self._flaky_p = 0.0

    # -- round-boundary hooks ------------------------------------------
    def crashes_due(self, now: float) -> list[str]:
        return self._pop_due("crash", now)

    def leaks_due(self, now: float) -> list[FaultEvent]:
        out = []
        for i, ev in enumerate(self.plan.events):
            if ev.kind == "page_leak" and i not in self._fired and now >= ev.at:
                self._fired.add(i)
                self.faults_injected += 1
                out.append(ev)
        return out

    def dispatch_stuck(self, engine: str, now: float) -> bool:
        """One-shot: the named engine's next dispatch at/after ``at`` hangs."""
        for i, ev in enumerate(self.plan.events):
            if (ev.kind == "stuck" and ev.engine == engine
                    and i not in self._fired and now >= ev.at):
                self._fired.add(i)
                self.faults_injected += 1
                return True
        return False

    def _pop_due(self, kind: str, now: float) -> list[str]:
        out = []
        for i, ev in enumerate(self.plan.events):
            if ev.kind == kind and i not in self._fired and now >= ev.at:
                self._fired.add(i)
                self.faults_injected += 1
                out.append(ev.engine)
        return out

    # -- per-migration-attempt hooks -----------------------------------
    def _arm_transfer_events(self, now: float) -> None:
        for i, ev in enumerate(self.plan.events):
            if i in self._fired or now < ev.at:
                continue
            if ev.kind in ("migration_fail", "migration_corrupt"):
                self._fired.add(i)
                self._fail_budget.append([ev.kind, ev.count])
            elif ev.kind == "migration_flaky":
                self._fired.add(i)
                self._flaky_p = max(self._flaky_p, ev.p)

    def transfer_outcome(self, now: float) -> str:
        """Fate of one KV-transfer attempt: 'ok' | 'fail' | 'corrupt'.
        Planned one-shot failures drain first, then the flaky coin flips
        (seeded — identical outcome sequence across replays)."""
        self._arm_transfer_events(now)
        while self._fail_budget:
            ent = self._fail_budget[0]
            if ent[1] <= 0:
                self._fail_budget.pop(0)
                continue
            ent[1] -= 1
            self.faults_injected += 1
            return "fail" if ent[0] == "migration_fail" else "corrupt"
        if self._flaky_p > 0.0 and self.rng.random() < self._flaky_p:
            self.faults_injected += 1
            return "fail"
        return "ok"

    def backoff_seconds(self, attempt: int, base: float) -> float:
        """Exponential backoff with seeded jitter, charged to the clock."""
        return base * (2.0 ** max(attempt - 1, 0)) * (1.0 + 0.5 * self.rng.random())
