"""Live serving gateway: asyncio streaming front end over ``PoolRuntime``.

The runtime (``cluster.runtime``) is a synchronous round-based scheduler; a
real deployment faces hundreds of concurrent clients that stream tokens,
disconnect mid-stream, time out, and arrive in bursts. This module bridges
the two worlds with ONE background thread that owns the runtime:

* the thread loops ``rt.step()`` under ``Gateway._lock`` and, after each
  round, polls every live stream's new tokens (``rt.generated_tokens`` +
  a per-stream emit offset) and fans them out to per-request
  ``asyncio.Queue``s via ``loop.call_soon_threadsafe`` — the only
  thread-safe way into the event loop;
* clients call ``await gateway.submit(...)`` and get a ``TokenStream``
  (async iterator of token ids); submission/cancellation take the same
  lock, so the runtime's single-threaded invariants hold.

Robustness pillars (the point of the layer):

* **cancellation** — ``TokenStream.cancel()`` (or the api layer, on client
  disconnect) aborts the request at any lifecycle stage through
  ``PoolRuntime.cancel``, which provably frees every KV page it held;
* **deadlines** — per-request TTFT/total deadlines ride on the ``Request``
  and are enforced by the runtime loop itself (``_enforce_deadlines``), so
  a gateway stall can never let a blown request keep burning FLOPs;
* **backpressure** — ``submit`` surfaces ``AdmissionRejected``
  synchronously when the bounded online queue is full; offline floods
  degrade through the runtime's defer/shed admission;
* **health & drain** — ``health()`` probes engine slots plus the PR 6
  crash/watchdog counters; ``drain()`` stops admission, lets in-flight
  streams run to completion or deadline, closes every client queue exactly
  once, then releases the retained page references (fault leases + prefix
  trees) so a leak-free shutdown ends with zero live pages per engine.

Eviction/crash recovery is invisible to streams by construction: greedy
regeneration is bit-identical, and the emit offset only advances — a
recovering request re-earns its prefix before new tokens flow.
"""
from __future__ import annotations

import asyncio
import threading
import time
from dataclasses import dataclass

from repro.cluster.runtime import AdmissionRejected, PoolRuntime, WallClock
from repro.core.request import Kind, Phase, Request

__all__ = ["Gateway", "GatewayClosed", "TokenStream", "AdmissionRejected"]

#: terminal outcomes a stream can report (exactly one per stream)
OUTCOMES = ("finished", "cancelled", "deadline", "error")


class GatewayClosed(RuntimeError):
    """Submit after the gateway stopped accepting (draining or stopped)."""


@dataclass
class _StreamState:
    """Gateway-side record of one live client stream."""
    rid: int
    req: Request
    queue: asyncio.Queue
    emitted: int = 0        # tokens already fanned out to the client
    closed: bool = False    # terminal event posted (exactly once)
    outcome: str | None = None


class TokenStream:
    """Async iterator over one request's output tokens.

    Yields token ids as the runtime produces them; iteration ends when the
    request reaches a terminal state, after which ``outcome`` is one of
    ``OUTCOMES``. ``cancel()`` aborts the request server-side (idempotent
    from the client's point of view: cancelling an already-terminal stream
    is a no-op here, unlike the strict ``PoolRuntime.cancel``)."""

    def __init__(self, gateway: "Gateway", req: Request,
                 queue: asyncio.Queue):
        self._gw = gateway
        self._q = queue
        self.req = req
        self.rid = req.rid
        self.outcome: str | None = None

    def __aiter__(self) -> "TokenStream":
        return self

    async def __anext__(self) -> int:
        if self.outcome is not None:
            raise StopAsyncIteration
        kind, payload = await self._q.get()
        if kind == "tok":
            return payload
        self.outcome = payload
        raise StopAsyncIteration

    async def cancel(self) -> bool:
        """Client-initiated abort; True if the request was still live."""
        return await self._gw.cancel(self.rid)


class Gateway:
    """Asyncio front end over a wall-clock ``PoolRuntime``.

    The runtime must use a ``WallClock`` (live serving); its ``interrupt``
    event is wired to the gateway's wake event so idle sleeps anywhere in
    the stack react to submits/cancels/shutdown within one slice."""

    def __init__(self, runtime: PoolRuntime, *, poll_interval: float = 0.005):
        if runtime.clock.virtual:
            raise ValueError(
                "Gateway drives live serving and needs a WallClock runtime; "
                "use PoolRuntime.run() for virtual-clock trace replay")
        self.rt = runtime
        self.poll_interval = poll_interval
        self._lock = threading.RLock()
        self._wake = threading.Event()
        if isinstance(runtime.clock, WallClock):
            runtime.clock.interrupt = self._wake
        self._streams: dict[int, _StreamState] = {}
        self._loop: asyncio.AbstractEventLoop | None = None
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()
        self._accepting = False
        self.crashed: BaseException | None = None

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    async def start(self) -> "Gateway":
        if self._thread is not None:
            raise RuntimeError("gateway already started")
        self._loop = asyncio.get_running_loop()
        self._accepting = True
        self._thread = threading.Thread(
            target=self._run_loop, name="gateway-runtime", daemon=True)
        self._thread.start()
        return self

    def _run_loop(self) -> None:
        """The runtime thread: step the pools, fan out tokens, sleep only
        when truly idle (and then interruptibly). Any exception escaping
        the scheduler closes every stream with the ``error`` outcome
        instead of leaving clients awaiting forever."""
        try:
            while not self._stop.is_set():
                self._wake.clear()
                with self._lock:
                    worked = self.rt.step()
                    self._publish()
                    idle = (not worked and not self.rt.online_queue
                            and not self.rt.offline_queue)
                if idle and not self._stop.is_set():
                    self._wake.wait(self.poll_interval)
        except BaseException as exc:  # noqa: BLE001 — surfaced to clients
            self.crashed = exc
            with self._lock:
                for st in list(self._streams.values()):
                    self._close_stream(st, "error")

    def _publish(self) -> None:
        """Fan new tokens out to client queues; close streams whose request
        reached a terminal state. Called with the lock held."""
        for st in list(self._streams.values()):
            toks = self.rt.generated_tokens(st.rid)
            while st.emitted < len(toks):
                self._post(st, ("tok", int(toks[st.emitted])))
                st.emitted += 1
            phase = st.req.phase
            if phase is Phase.FINISHED:
                self._close_stream(st, "finished")
            elif phase is Phase.CANCELLED:
                self._close_stream(st, "deadline"
                                   if st.req.cancel_reason == "deadline"
                                   else "cancelled")

    def _close_stream(self, st: _StreamState, outcome: str) -> None:
        """Terminal event, exactly once per stream (guarded by ``closed``
        and removal from the live map)."""
        if st.closed:
            return
        st.closed = True
        st.outcome = outcome
        self._streams.pop(st.rid, None)
        self._post(st, ("end", outcome))

    def _post(self, st: _StreamState, item: tuple) -> None:
        loop = self._loop
        if loop is None or loop.is_closed():
            return  # client world is gone; dropping the event is all we can do
        try:
            loop.call_soon_threadsafe(st.queue.put_nowait, item)
        except RuntimeError:
            pass  # loop closed between the check and the call

    # ------------------------------------------------------------------
    # client API
    # ------------------------------------------------------------------
    async def submit(self, prompt_tokens: list[int], *,
                     kind: Kind = Kind.ONLINE, max_new_tokens: int = 16,
                     ttft_deadline: float | None = None,
                     total_deadline: float | None = None) -> TokenStream:
        """Admit one request and return its token stream.

        Raises ``AdmissionRejected`` (backpressure), ``ValueError``
        (malformed prompt), or ``GatewayClosed`` (draining/stopped) — all
        synchronously, before the client ever waits on the stream."""
        if not self._accepting:
            raise GatewayClosed("gateway is draining or stopped")
        queue: asyncio.Queue = asyncio.Queue()
        toks = [int(t) for t in prompt_tokens]

        def _admit() -> Request:
            with self._lock:
                req = Request(kind, self.rt.clock.now(), len(toks),
                              max(int(max_new_tokens), 1),
                              ttft_deadline=ttft_deadline,
                              total_deadline=total_deadline)
                self.rt.submit(req, toks)   # may raise; nothing registered yet
                self._streams[req.rid] = _StreamState(req.rid, req, queue)
                return req

        req = await asyncio.to_thread(_admit)
        self._wake.set()
        return TokenStream(self, req, queue)

    async def cancel(self, rid: int) -> bool:
        """Abort a live request (client disconnect path). Returns True if
        it was still live, False if it already reached a terminal state —
        the benign disconnect/finish race is not an error here."""
        def _do() -> bool:
            with self._lock:
                st = self._streams.get(rid)
                try:
                    self.rt.cancel(rid)
                except ValueError:
                    return False
                if st is not None:
                    self._close_stream(st, "cancelled")
                return True

        live = await asyncio.to_thread(_do)
        self._wake.set()
        return live

    def health(self) -> dict:
        """Engine-slot liveness + PR 6 fault counters + gateway state."""
        with self._lock:
            out = self.rt.health()
        out["accepting"] = self._accepting
        out["live_streams"] = len(self._streams)
        if self.crashed is not None:
            out["status"] = "dead"
            out["gateway_error"] = repr(self.crashed)
        return out

    # ------------------------------------------------------------------
    # shutdown
    # ------------------------------------------------------------------
    def _work_pending(self) -> bool:
        rt = self.rt
        resident = any(s.resident or s.prefilling
                       for s in rt.strict_pool + rt.relaxed_pool)
        return bool(self._streams or rt.online_queue or rt.offline_queue
                    or rt.place_queue or resident)

    async def drain(self, timeout: float = 60.0) -> dict:
        """Graceful shutdown: stop admission, let in-flight streams run to
        completion (or their deadlines), force-cancel whatever outlives
        ``timeout``, stop the runtime thread, then release retained page
        references (fault leases + prefix trees). Returns a report whose
        ``leaked_pages`` must be all-zero — asserted by the load harness
        and the gateway tests."""
        self._accepting = False
        with self._lock:
            self.rt.draining = True
        deadline = time.monotonic() + timeout
        while True:
            with self._lock:
                pending = self._work_pending()
            if not pending or self.crashed is not None:
                break
            if time.monotonic() >= deadline:
                with self._lock:
                    for st in list(self._streams.values()):
                        try:
                            self.rt.cancel(st.rid)
                        except ValueError:
                            pass
                        self._close_stream(st, "cancelled")
                break
            await asyncio.sleep(0.01)
        await self.stop()
        with self._lock:
            released = self.rt.release_retained()
            leaks = self.rt.live_pages()
            summary = self.rt.summary()
        return {
            "leaked_pages": leaks,
            "released_retained": released,
            "drained": summary["drained"],
            "summary": summary,
        }

    async def stop(self) -> None:
        """Stop the runtime thread (does not touch runtime state; use
        ``drain`` for the graceful leak-free path)."""
        self._accepting = False
        self._stop.set()
        self._wake.set()
        if self._thread is not None:
            await asyncio.to_thread(self._thread.join)
            self._thread = None
