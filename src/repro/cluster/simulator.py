"""Discrete-event simulator of the latency-disaggregated cluster (§3.1–3.4).

Instances are mesh-slice analogues of xllm instances; their step durations
come from the roofline perf model (the paper validates it at ≈5 % error, and
we re-validate against real timed engine runs in the benchmarks). The three
policies of §5.1.4 — base_pd, online_priority, ooco — share the event loop
and differ only in the scheduling decisions, which for OOCO are the *same
functions* (`core.scheduling`) the real engine executes.

Time model:
  online request:  arrive -> relaxed prefill queue -> prefill (layer-
  interruptible under ooco) -> KV migration (bytes/B_c) -> strict decode
  batch -> finish.   TTFT = prefill completion; TPOT = decode step times.
  offline request:  gated prefill on relaxed -> decode on relaxed (ooco) or
  migrate to strict; evictable (recompute) when online needs the space.
"""
from __future__ import annotations

import heapq
import itertools
import random
from dataclasses import dataclass, field

import numpy as np

from repro.core import scheduling as sch
from repro.core.perf_model import PerfModel
from repro.core.request import Kind, Phase, Request
from repro.data.traces import TraceRequest


@dataclass
class SimConfig:
    slo_ttft: float = 4.0
    slo_tpot: float = 0.10
    n_relaxed: int = 1
    n_strict: int = 1
    tp: int = 1
    kv_util: float = 0.90          # HBM fraction usable for KV after weights
    duration: float = 600.0
    violation_threshold: float = 0.03
    gating_horizon: float = 20.0   # §3.4.2 cost-model horizon (s)
    seed: int = 0
    offline_relaxed_batch_cap: int = 256


@dataclass
class InstanceState:
    iid: str
    kind: str                       # "relaxed" | "strict"
    resident: dict[int, Request] = field(default_factory=dict)
    serial: int = 0                 # quantum serial (stale-event filter)
    idle: bool = True
    # current prefill job (relaxed only)
    cur_req: Request | None = None
    cur_start: float = 0.0
    cur_end: float = 0.0
    cur_layer_dt: float = 0.0
    cur_total_layers: int = 0
    cur_done_layers: int = 0        # layers completed before this quantum
    busy_until: float = 0.0


class Simulator:
    def __init__(self, cfg_model, hw, policy: str, sim: SimConfig):
        self.cfg = cfg_model
        self.hw = hw
        self.pm = PerfModel(cfg_model, hw, tp=sim.tp)
        self.policy = policy
        self.sim = sim
        self.rng = random.Random(sim.seed)
        self.kv_budget = hw.hbm_capacity * sim.kv_util - self.pm.weight_bytes()
        assert self.kv_budget > 0, "model weights do not fit the instance"
        self.relaxed = [InstanceState(f"relaxed{i}", "relaxed")
                        for i in range(sim.n_relaxed)]
        self.strict = [InstanceState(f"strict{i}", "strict")
                       for i in range(sim.n_strict)]
        self.instances = {i.iid: i for i in self.relaxed + self.strict}
        self.online_queue: list[Request] = []      # waiting for prefill
        self.offline_queue: list[Request] = []     # waiting for (re)prefill
        self.events: list = []
        self._seq = itertools.count()
        self.now = 0.0
        self.online_done: list[Request] = []
        self.offline_tokens = 0
        self.offline_done = 0
        self.counters = {"relaxed_decode_quanta": 0, "relaxed_decode_tokens": 0,
                         "strict_offline_tokens": 0, "pulled": 0,
                         "prefills_online": 0, "prefills_offline": 0,
                         "interruptions": 0}
        self.all_online: list[Request] = []
        self.n_layers = cfg_model.num_layers + cfg_model.encoder_layers

    # ------------------------------------------------------------------
    def _push(self, t: float, kind: str, payload=None):
        heapq.heappush(self.events, (t, next(self._seq), kind, payload))

    def _wake(self, inst: InstanceState, t: float):
        if inst.idle:
            inst.idle = False
            inst.serial += 1
            self._push(t, "ready", (inst.iid, inst.serial))

    def kv_used(self, inst: InstanceState) -> float:
        if not inst.resident:
            return 0.0
        return self.pm.kv_bytes([r.context_len for r in inst.resident.values()])

    # ------------------------------------------------------------------
    def run(self, online: list[TraceRequest], offline: list[TraceRequest]) -> dict:
        for tr in online:
            r = Request(Kind.ONLINE, tr.arrival, tr.prompt_len, tr.output_len)
            self.all_online.append(r)
            self._push(tr.arrival, "arrive", r)
        for tr in offline:
            r = Request(Kind.OFFLINE, tr.arrival, tr.prompt_len, tr.output_len)
            self._push(tr.arrival, "arrive", r)
        end = self.sim.duration
        while self.events:
            t, _, kind, payload = heapq.heappop(self.events)
            if t > end:
                break
            self.now = t
            if kind == "arrive":
                self._on_arrive(payload)
            elif kind == "ready":
                iid, serial = payload
                inst = self.instances[iid]
                if serial == inst.serial:
                    self._on_ready(inst)
            elif kind == "migrate_done":
                self._on_migrate_done(*payload)
            elif kind == "dispatch_retry":
                req, src_iid = payload
                self._dispatch_to_strict(req, self.instances[src_iid])
        return self._metrics()

    # ------------------------------------------------------------------
    def _on_arrive(self, req: Request):
        if req.kind == Kind.ONLINE:
            self.online_queue.append(req)
            inst = min(self.relaxed, key=lambda i: i.busy_until)
            if self.policy == "ooco":
                self._maybe_interrupt(inst)
            self._wake(inst, self.now)
        else:
            self.offline_queue.append(req)
            for inst in self.relaxed:
                self._wake(inst, self.now)

    def _maybe_interrupt(self, inst: InstanceState):
        """§3.4.1 layer-level interruption of a running OFFLINE prefill."""
        cur = inst.cur_req
        if cur is None or cur.kind != Kind.OFFLINE or inst.kind != "relaxed":
            return
        if not self.online_queue:
            return
        done_f = (self.now - inst.cur_start) / max(inst.cur_layer_dt, 1e-9)
        boundary_layers = int(np.ceil(done_f))
        boundary_t = inst.cur_start + boundary_layers * inst.cur_layer_dt
        if boundary_t >= inst.cur_end - 1e-12:
            return  # about to finish anyway
        # truncate the quantum at the next layer boundary
        cur.prefill_layers_done = inst.cur_done_layers + boundary_layers
        cur.phase = Phase.QUEUED
        self.offline_queue.insert(0, cur)   # resume later, keep progress
        inst.cur_req = None
        self.counters["interruptions"] += 1
        inst.serial += 1
        inst.idle = False
        inst.busy_until = boundary_t
        self._push(boundary_t, "ready", (inst.iid, inst.serial))

    # ------------------------------------------------------------------
    def _on_ready(self, inst: InstanceState):
        if inst.kind == "strict":
            self._strict_quantum(inst)
        else:
            self._relaxed_quantum(inst)

    # ------------------- strict (decode) -------------------------------
    def _strict_quantum(self, inst: InstanceState):
        reqs = list(inst.resident.values())
        online = [r for r in reqs if r.kind == Kind.ONLINE]
        offline = [r for r in reqs if r.kind == Kind.OFFLINE]
        batch = self._select_decode(inst, online, offline)
        if not batch:
            inst.idle = True
            return
        est = self.pm.decode_estimate([r.context_len for r in batch])
        inst.last_bottleneck = est.bottleneck
        lat = est.latency
        # strict-pool pressure EMA feeds the gating cost model (§3.4.2):
        # eviction risk is real only when decode runs near the TPOT SLO
        online_lat = (self.pm.decode_estimate(
            [r.context_len for r in online]).latency if online else 0.0)
        self._pressure = 0.9 * getattr(self, "_pressure", 0.0) + 0.1 * min(
            online_lat / self.sim.slo_tpot, 1.0)
        t_end = self.now + lat
        for r in batch:
            r.generated += 1
            r.decode_time_sum += lat
            if r.kind == Kind.OFFLINE:
                self.offline_tokens += 1
                self.counters["strict_offline_tokens"] += 1
            if r.done:
                r.phase = Phase.FINISHED
                r.finish_time = t_end
                inst.resident.pop(r.rid, None)
                if r.kind == Kind.ONLINE:
                    self.online_done.append(r)
                else:
                    self.offline_done += 1
        # §3.4.3 pull-model migration (ooco only), concurrent with compute
        if self.policy == "ooco" and any(
                r.kind == Kind.OFFLINE for ri in self.relaxed
                for r in ri.resident.values()):
            self._pull_migration(inst, batch)
        inst.busy_until = t_end
        inst.serial += 1
        inst.idle = False
        self._push(t_end, "ready", (inst.iid, inst.serial))

    def _select_decode(self, inst, online, offline) -> list[Request]:
        slo = self.sim.slo_tpot
        if self.policy == "base_pd":
            return online + offline  # no SLO-aware selection at all
        if self.policy == "online_priority":
            # static decode-batch cap calibrated once at a conservative long
            # context (existing co-location systems lack a per-step roofline
            # model — HyGen/Echo-style heuristics, paper §5.1.4/§6)
            cap = getattr(self, "_op_cap", None)
            if cap is None:
                p95 = 4096  # conservative context assumption
                lo, hi = 1, 4096
                while lo < hi:
                    mid = (lo + hi + 1) // 2
                    if self.pm.decode_estimate([p95] * mid).latency <= slo:
                        lo = mid
                    else:
                        hi = mid - 1
                cap = self._op_cap = lo
            rest = sorted(offline, key=lambda x: x.context_len)
            return (online + rest)[:max(cap, len(online))]
        return sch.mix_decoding_selection(
            online, offline, slo, self.pm, rng=self.rng,
            mem_budget_bytes=self.kv_budget)

    def _pull_migration(self, inst: InstanceState, batch):
        all_included = len(batch) == len(inst.resident)
        pref = sch.migration_decision(
            batch, all_included, self.sim.slo_tpot, self.pm,
            mem_budget_bytes=self.kv_budget - self.kv_used(inst))
        if pref is None:
            return
        candidates = [r for ri in self.relaxed
                      for r in ri.resident.values() if r.kind == Kind.OFFLINE]
        chosen = sch.select_for_migration(candidates, pref)
        for r in chosen:
            self.counters["pulled"] += 1
            src = self.instances[r.location]
            src.resident.pop(r.rid, None)
            r.phase = Phase.MIGRATING
            delay = self.pm.migration_seconds(r.context_len)
            self._push(self.now + delay, "migrate_done", (r, inst.iid))

    # ------------------- relaxed (prefill + offline decode) ------------
    def _relaxed_quantum(self, inst: InstanceState):
        # 1) finish the quantum that just ended
        if inst.cur_req is not None:
            self._finish_prefill(inst, inst.cur_req)
            inst.cur_req = None
        # 2) pick next work
        nxt = self._next_prefill(inst)
        if nxt is not None:
            self._start_prefill(inst, nxt)
            return
        # 3) ooco: offline decode on the latency-relaxed instance
        if self.policy == "ooco" and inst.resident:
            self.counters["relaxed_decode_quanta"] += 1
            reqs = sorted(inst.resident.values(), key=lambda r: r.context_len)
            batch = reqs[: self.sim.offline_relaxed_batch_cap]
            est = self.pm.decode_estimate([r.context_len for r in batch])
            t_end = self.now + est.latency
            for r in batch:
                r.generated += 1
                r.decode_time_sum += est.latency
                self.offline_tokens += 1
                self.counters["relaxed_decode_tokens"] += 1
                if r.done:
                    r.phase = Phase.FINISHED
                    r.finish_time = t_end
                    inst.resident.pop(r.rid, None)
                    self.offline_done += 1
            inst.busy_until = t_end
            inst.serial += 1
            inst.idle = False
            self._push(t_end, "ready", (inst.iid, inst.serial))
            return
        inst.idle = True

    def _next_prefill(self, inst) -> Request | None:
        if self.policy == "base_pd":
            # FIFO over both kinds: offline prefill head-of-line blocks online
            merged = sorted(self.online_queue + self.offline_queue,
                            key=lambda r: r.arrival)
            for r in merged:
                if self._admit_prefill(inst, r):
                    (self.online_queue if r.kind == Kind.ONLINE
                     else self.offline_queue).remove(r)
                    return r
            return None
        if self.online_queue:
            r = self.online_queue.pop(0)
            return r
        # offline prefill only when no online work (both ooco + online_priority)
        used = self.kv_used(inst)
        budget_left = self.kv_budget - used
        for r in list(self.offline_queue)[:4]:  # FIFO head, bounded scan
            if self.pm.kv_bytes([r.context_len]) > budget_left:
                continue
            if self.policy == "ooco" and r.prefill_layers_done == 0:
                ok = sch.gating_decision(
                    r, list(inst.resident.values()), self.pm,
                    evict_probability=self._evict_probability(),
                    horizon_seconds=self.sim.gating_horizon,
                    mem_budget_bytes=budget_left)
                if not ok:
                    continue
            self.offline_queue.remove(r)
            return r
        return None

    def _admit_prefill(self, inst, r: Request) -> bool:
        need = self.pm.kv_bytes([r.context_len])
        return self.kv_used(inst) + need <= self.kv_budget

    def _evict_probability(self) -> float:
        """Eviction-risk estimate for the gating cost model (§3.4.2):
        offline requests only get evicted when online decode pressure on the
        strict pool approaches the SLO, so use that pressure EMA."""
        return 0.5 * getattr(self, "_pressure", 0.0)

    def _start_prefill(self, inst, req: Request):
        est = self.pm.prefill_estimate([req.context_len])
        frac = 1.0 - req.prefill_layers_done / self.n_layers
        dur = est.latency * frac
        req.phase = Phase.PREFILLING
        self.counters["prefills_online" if req.kind == Kind.ONLINE
                      else "prefills_offline"] += 1
        inst.cur_req = req
        inst.cur_start = self.now
        inst.cur_end = self.now + dur
        inst.cur_layer_dt = est.latency / self.n_layers
        inst.cur_done_layers = req.prefill_layers_done
        inst.busy_until = inst.cur_end
        inst.serial += 1
        inst.idle = False
        self._push(inst.cur_end, "ready", (inst.iid, inst.serial))

    def _finish_prefill(self, inst, req: Request):
        req.prefill_layers_done = self.n_layers
        req.prefill_end = self.now
        if req.generated == 0:
            req.generated = 1           # prefill emits the first token
            if req.kind == Kind.OFFLINE:
                self.offline_tokens += 1
            if req.first_token_time is None:
                req.first_token_time = self.now
            if req.done:
                req.phase = Phase.FINISHED
                req.finish_time = self.now
                if req.kind == Kind.ONLINE:
                    self.online_done.append(req)
                else:
                    self.offline_done += 1
                return
        if req.kind == Kind.ONLINE or self.policy != "ooco":
            self._dispatch_to_strict(req, inst)
        else:
            # ooco offline: decode on the relaxed node until pulled
            req.phase = Phase.DECODING
            req.location = inst.iid
            inst.resident[req.rid] = req

    def _dispatch_to_strict(self, req: Request, src: InstanceState):
        """Move a prefilled request to a strict instance (push model for
        online, §3.4.3; baselines use it for offline too). KV transfer is
        modeled at B_c bytes/s (RDMA->ICI adaptation, DESIGN §3)."""
        dst = max(self.strict, key=lambda i: self.kv_budget - self.kv_used(i))
        need = self.pm.kv_bytes([req.context_len])
        free = self.kv_budget - self.kv_used(dst)
        if need > free:
            freed = self._evict_for(dst, need - free, requester=req)
            free += freed
        if need > free:
            # cannot fit yet — retry shortly (KV stays at the source)
            self._push(self.now + 0.025, "dispatch_retry", (req, src.iid))
            return
        req.phase = Phase.MIGRATING
        delay = self.pm.migration_seconds(req.context_len)
        self._push(self.now + delay, "migrate_done", (req, dst.iid))

    def _evict_for(self, dst: InstanceState, need_bytes: float,
                   requester: Request) -> float:
        """Free KV space on a strict instance for an incoming request."""
        offline = [r for r in dst.resident.values() if r.kind == Kind.OFFLINE]
        if self.policy == "base_pd":
            # vLLM-style recompute preemption: latest arrival first, any kind
            victims_pool = sorted(dst.resident.values(),
                                  key=lambda r: -r.arrival)
        elif self.policy == "online_priority":
            victims_pool = sorted(offline, key=lambda r: r.context_len)
        else:  # ooco: bottleneck-aware victim selection (§3.4.1)
            per_tok = self.pm.kv_bytes_per_token() / self.sim.tp
            need_tokens = (int(np.ceil(need_bytes / per_tok)) if per_tok > 0
                           else sum(r.context_len for r in offline))
            bn = getattr(dst, "last_bottleneck", "memory")
            victims_pool = sch.select_eviction_victims(offline, need_tokens, bn)
        freed = 0.0
        for v in victims_pool:
            if freed >= need_bytes:
                break
            freed += self.pm.kv_bytes([v.context_len])
            dst.resident.pop(v.rid, None)
            v.phase = Phase.EVICTED
            v.evictions += 1
            v.recompute_tokens += v.context_len
            v.prefill_layers_done = 0
            if v.kind == Kind.ONLINE:
                # recompute: goes back through the online prefill queue
                self.online_queue.append(v)
            else:
                self.offline_queue.append(v)
        for inst in self.relaxed:
            self._wake(inst, self.now)
        return freed

    # ------------------------------------------------------------------
    def _on_migrate_done(self, req: Request, iid: str):
        inst = self.instances[iid]
        req.phase = Phase.DECODING
        req.location = iid
        inst.resident[req.rid] = req
        self._wake(inst, self.now)

    # ------------------------------------------------------------------
    def _metrics(self) -> dict:
        end = self.sim.duration
        counted = [r for r in self.all_online if r.arrival <= end]
        viol = sum(1 for r in counted
                   if r.violates(self.sim.slo_ttft, self.sim.slo_tpot, now=end))
        n = max(len(counted), 1)
        ttfts = [r.ttft() for r in counted if r.ttft() is not None]
        tpots = [r.avg_tpot() for r in counted if r.avg_tpot() is not None]
        return {
            "policy": self.policy,
            "online_requests": len(counted),
            "online_violation_rate": viol / n,
            "online_p50_ttft": float(np.median(ttfts)) if ttfts else float("nan"),
            "online_p99_ttft": float(np.percentile(ttfts, 99)) if ttfts else float("nan"),
            "online_p50_tpot": float(np.median(tpots)) if tpots else float("nan"),
            "offline_tokens": self.offline_tokens,
            "offline_token_throughput": self.offline_tokens / end,
            "offline_completed": self.offline_done,
            "offline_request_throughput": self.offline_done / end,
            **{f"c_{k}": v for k, v in self.counters.items()},
        }
