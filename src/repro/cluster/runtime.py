"""Pool-based co-located serving runtime: N strict + M relaxed REAL engines.

This is the cluster layer of the paper (§3.1–3.4) executing on actual
``ServingEngine`` instances instead of the discrete-event simulator: the
latency-strict pool decodes online traffic under the TPOT SLO, the
latency-relaxed pool absorbs prefills and offline decoding, and every
scheduling point routes through the *same* ``core.scheduling`` functions and
the Roofline ``PerfModel`` the simulator uses — ``last_bottleneck`` per
instance steers eviction-victim selection, the strict-pool pressure EMA
feeds the §3.4.2 gating cost model, and the §3.4.3 pull migration moves real
KV pages between any relaxed→strict engine pair.

Clocking is pluggable:

* ``WallClock`` — live serving; step latencies are measured, idle rounds
  sleep until the next arrival instead of spinning.
* ``VirtualClock`` — **deterministic trace replay**: tokens come from the
  real JAX compute, but time advances by the perf model's modeled step
  latencies, so two replays of the same trace produce bit-identical token
  streams, finished sets, and metrics (the foundation for policy
  regression gates — see tests/test_colocation_runtime.py and the
  ``colocation-replay`` CI step).

Pools execute in parallel in a real deployment, so a virtual round advances
by the *maximum* modeled cost across engines; each engine's actions within
a round (prefill, then decode) are serialized and their costs summed.

Fault tolerance (``cluster.faults``): a seeded ``FaultPlan`` can crash
engines, wedge dispatches, leak pool pages, and fail/corrupt KV transfers —
all deterministically, so chaos replays are bit-reproducible. Crashed
engines' in-flight requests are re-admitted from the frontend prompt log
through the recompute path (greedy streams regenerate bit-identical
tokens); a crashed strict engine promotes a drained relaxed engine; KV
migration retries with seeded backoff and falls back to recompute; and
under overload, admission control defers (optionally sheds) offline work
first so online SLO attainment decays last.
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.cluster.faults import FaultInjector, FaultPlan
from repro.core import scheduling as sch
from repro.core.hardware import cpu_measured
from repro.core.perf_model import HardwareParams, PerfModel
from repro.core.request import Kind, Phase, Request
from repro.data.traces import TraceRequest
from repro.engine.engine import ServingEngine
from repro.engine.kv_cache import TransferIntegrityError
from repro.models.model import build_model

POLICIES = ("base_pd", "online_priority", "ooco")


def replay_hw(profile: str = "cpu") -> HardwareParams:
    """Replay calibration presets for the virtual clock.

    ``"cpu"`` (default, alias ``"cpu_scale"``): the reduced smoke-test
    models serve requests of tens of tokens, so with datacenter rates every
    step would collapse into the static overhead and no policy could be
    distinguished. This calibration scales the achievable rates down so
    that reduced-model request sizes reproduce the full-scale bottleneck
    structure: decode attention is memory-bound and grows with context
    length, GEMMs saturate within a few tens of requests, and the per-step
    overhead stays a minority term.

    ``"v5e"``: datacenter-ratio preset — the TPU v5e achievable rates
    scaled down uniformly so reduced-model work takes simulable time, but
    with the FULL v5e dispatch overheads (O_p=8ms, O_d=4ms) kept as-is.
    The overhead:work ratio therefore matches the real chip (per-dispatch
    overhead is a large fraction of a small decode step), which is the
    regime where multi-step horizons and fused mixed horizons pay — the
    datacenter-scale replay the ROADMAP calls for.

    All presets are fixed constants — never measured — so virtual-clock
    replays are machine-independent.
    """
    if profile in ("cpu", "cpu_scale"):
        return HardwareParams(
            name="replay_cpu_scale",
            F_g=5e9, F_ap=3e9, F_ad=1e9,
            M_g=1e9, M_a=2e7,
            O_p=2e-3, O_d=1e-3,
            B_c=1e8, hbm_capacity=64e6,
            peak_flops=5e9, peak_hbm_bw=1e9)
    if profile == "v5e":
        from repro.core.hardware import TPU_V5E
        # s=100 keeps a reduced-model weight stream (~20 MB -> ~2 ms)
        # under the unscaled O_d (4 ms), preserving the real chip's
        # overhead-dominated decode steps; a much larger s would invert
        # the ratio (streaming above overhead) and no horizon could ever
        # pay, which is the cpu-scale regime, not the datacenter one
        s = 100.0
        return HardwareParams(
            name="replay_v5e_scale",
            F_g=TPU_V5E.F_g / s, F_ap=TPU_V5E.F_ap / s,
            F_ad=TPU_V5E.F_ad / s,
            M_g=TPU_V5E.M_g / s, M_a=TPU_V5E.M_a / s,
            O_p=TPU_V5E.O_p, O_d=TPU_V5E.O_d,
            B_c=TPU_V5E.B_c / s, hbm_capacity=64e6,
            peak_flops=TPU_V5E.peak_flops / s,
            peak_hbm_bw=TPU_V5E.peak_hbm_bw / s)
    raise ValueError(f"unknown replay_hw profile {profile!r}; "
                     "expected 'cpu' or 'v5e'")


# ---------------------------------------------------------------------------
# clocks
# ---------------------------------------------------------------------------

class WallClock:
    """Live-serving clock: real time, bounded sleep when idle.

    ``interrupt`` (an optional ``threading.Event``) makes idle sleeps
    responsive to live signals: the gateway sets it on submit / cancel /
    shutdown so a long idle gap never delays reacting to a client by more
    than one slice. Without an event, plain ``time.sleep`` slices give the
    same bounded-latency property to signal handlers."""

    virtual = False

    #: max seconds one idle sleep may block before re-checking for signals
    IDLE_SLICE = 0.005

    def __init__(self, interrupt=None):
        self._t0 = time.perf_counter()
        self.interrupt = interrupt

    def now(self) -> float:
        return time.perf_counter() - self._t0

    def advance(self, dt: float) -> None:
        pass  # wall time advances by itself

    def reset(self) -> None:
        """Re-anchor t=0 (run() calls this so engine construction and
        import time never count against trace-relative TTFTs)."""
        self._t0 = time.perf_counter()

    def idle_until(self, t: float) -> None:
        """Sleep toward t in small interruptible slices (the busy-loop fix:
        idle rounds must not spin ``step()`` and dilute measured
        throughput; the slice bound keeps cancel/shutdown latency under
        ``IDLE_SLICE`` even across a long idle gap)."""
        while True:
            delta = t - self.now()
            if delta <= 0:
                return
            nap = min(delta, self.IDLE_SLICE)
            if self.interrupt is not None:
                if self.interrupt.wait(nap):
                    return  # woken by a live signal: let the caller react
            else:
                time.sleep(nap)


class VirtualClock:
    """Deterministic replay clock: time is whatever the perf model says."""

    virtual = True

    def __init__(self, start: float = 0.0):
        self._now = float(start)

    def now(self) -> float:
        return self._now

    def advance(self, dt: float) -> None:
        self._now += float(dt)

    def idle_until(self, t: float) -> None:
        self._now = max(self._now, float(t))

    def reset(self) -> None:
        pass  # virtual time only moves by advance()/idle_until()


# ---------------------------------------------------------------------------
# pool state + metrics
# ---------------------------------------------------------------------------

@dataclass
class EngineSlot:
    """One engine instance plus the §3.4 per-instance scheduling state."""
    name: str
    role: str                      # "strict" | "relaxed"
    engine: ServingEngine
    online: list[Request] = field(default_factory=list)
    offline: list[Request] = field(default_factory=list)
    # chunk-granular prefills in progress on this engine (KV pinned here);
    # index 0 is the one currently advancing
    prefilling: list = field(default_factory=list)
    last_bottleneck: str = "memory"
    pressure: float = 0.0          # strict-pool online-latency EMA (§3.4.2)

    @property
    def resident(self) -> int:
        return len(self.online) + len(self.offline)


@dataclass
class Metrics:
    """Runtime counters; ``PoolRuntime.summary()`` turns these plus the
    per-request SLO accounting into the policy-comparison record."""
    rounds: int = 0
    idle_rounds: int = 0
    migrations: int = 0
    pulls: int = 0
    evictions: int = 0
    chunks: int = 0                # prefill chunks executed (fused rounds)
    chunk_preemptions: int = 0     # §3.4.1 pauses at chunk boundaries
    horizon_rounds: int = 0        # rounds dispatched as K>1 decode horizons
    mixed_horizon_rounds: int = 0  # rounds dispatched as K>1 fused mixed
                                   # horizons (chunk + decode in one scan)
    engine_crashes: int = 0        # fault injection: engines lost
    promotions: int = 0            # relaxed->strict failover promotions
    recoveries: int = 0            # requests re-admitted after a crash
    migration_retries: int = 0     # failed KV-transfer attempts retried
    migration_recomputes: int = 0  # transfers that fell back to recompute
    watchdog_aborts: int = 0       # stuck dispatches killed by the watchdog
    shed_requests: int = 0         # offline work shed under bounded backlog
    degraded_rounds: int = 0       # rounds run under overload admission
    cancelled: int = 0             # client-cancelled requests (any stage)
    deadline_aborts: int = 0       # requests aborted past their deadline
    rejected_online: int = 0       # online submits bounced at admission
    drained: int = 0               # requests finished during graceful drain
    prefill_modeled_seconds: float = 0.0  # modeled prefill compute (chunk-
                                   # only share of fused rounds) — the
                                   # denominator of effective prefill tok/s


def _pct(xs: list[float], q: float) -> float | None:
    return float(np.percentile(xs, q)) if xs else None


class AdmissionRejected(RuntimeError):
    """Online submit bounced by backpressure: the bounded online admission
    queue is full. Raised synchronously from ``submit`` so the caller (the
    gateway) can fail the client fast instead of letting an online flood
    grow host state without bound. Offline floods degrade through the
    existing defer/shed path (``admission_decision``) and never raise."""


def _validate_runtime_args(*, policy, n_strict, n_relaxed, slo_ttft, slo_tpot,
                           num_pages, page_size, decode_horizon, max_horizon,
                           chunk_tokens, max_transfer_attempts,
                           max_offline_backlog, max_online_queue) -> None:
    """Constructor-time validation: reject impossible topologies, SLOs, and
    scheduling knobs with actionable ``ValueError``s instead of the index/
    shape errors they would otherwise become deep inside a replay."""
    if policy not in POLICIES:
        raise ValueError(f"unknown policy {policy!r}; "
                         f"expected one of {POLICIES}")
    if n_strict + n_relaxed <= 0:
        raise ValueError("PoolRuntime needs at least one engine "
                         f"(n_strict={n_strict}, n_relaxed={n_relaxed})")
    if n_strict < 1 or n_relaxed < 1:
        raise ValueError(
            "PoolRuntime needs >= 1 strict and >= 1 relaxed engine (got "
            f"n_strict={n_strict}, n_relaxed={n_relaxed}): the strict pool "
            "serves online decode, the relaxed pool runs prefill. Pools "
            "may still shrink to zero at runtime via fault injection.")
    if slo_ttft <= 0 or slo_tpot <= 0:
        raise ValueError("SLOs must be positive seconds "
                         f"(slo_ttft={slo_ttft}, slo_tpot={slo_tpot})")
    if num_pages < 2 or page_size < 1:
        raise ValueError("KV pool needs num_pages >= 2 (page 0 is reserved) "
                         f"and page_size >= 1 (got num_pages={num_pages}, "
                         f"page_size={page_size})")
    if max_horizon < 1:
        raise ValueError(f"max_horizon must be >= 1 (got {max_horizon})")
    for knob, val in (("decode_horizon", decode_horizon),
                      ("chunk_tokens", chunk_tokens)):
        if val in (None, "auto"):
            continue
        try:
            n = int(val)
        except (TypeError, ValueError):
            raise ValueError(f"{knob} must be an int >= 0, 'auto', or None "
                             f"(got {val!r})") from None
        if n < 0:
            raise ValueError(f"{knob} must be >= 0 (got {val!r}; "
                             "0/None disables the feature)")
    if max_transfer_attempts < 1:
        raise ValueError("max_transfer_attempts must be >= 1 "
                         f"(got {max_transfer_attempts})")
    if max_offline_backlog is not None and max_offline_backlog < 0:
        raise ValueError("max_offline_backlog must be None or >= 0 "
                         f"(got {max_offline_backlog})")
    if max_online_queue is not None and max_online_queue < 1:
        raise ValueError("max_online_queue must be None (unbounded) or >= 1 "
                         f"(got {max_online_queue})")


class PoolRuntime:
    """N-strict + M-relaxed co-located serving over real JAX engines."""

    def __init__(self, cfg, *, policy: str = "ooco", n_strict: int = 1,
                 n_relaxed: int = 1, clock=None, slo_ttft: float = 4.0,
                 slo_tpot: float = 1.0, num_pages: int = 512,
                 page_size: int = 16, seed: int = 0, backend: str = "auto",
                 hw: HardwareParams | None = None,
                 decode_buckets: tuple[int, ...] = (8,),
                 relaxed_decode_cap: int = 16,
                 gating_horizon: float = 20.0,
                 chunk_tokens: int | str | None = "auto",
                 decode_horizon: int | str | None = 1,
                 max_horizon: int = 16,
                 fault_plan=None, chaos_seed: int = 0,
                 max_transfer_attempts: int = 3,
                 backoff_base: float = 0.05,
                 watchdog_mult: float = 10.0,
                 max_offline_backlog: int | None = None,
                 max_online_queue: int | None = None,
                 prefix_cache: bool = True,
                 model=None, params=None,
                 kernels_from: ServingEngine | None = None):
        _validate_runtime_args(
            policy=policy, n_strict=n_strict, n_relaxed=n_relaxed,
            slo_ttft=slo_ttft, slo_tpot=slo_tpot, num_pages=num_pages,
            page_size=page_size, decode_horizon=decode_horizon,
            max_horizon=max_horizon, chunk_tokens=chunk_tokens,
            max_transfer_attempts=max_transfer_attempts,
            max_offline_backlog=max_offline_backlog,
            max_online_queue=max_online_queue)
        self.cfg = cfg
        self.policy = policy
        # chunked-prefill token budget: "auto" = roofline-suggested per
        # round (PerfModel.suggest_chunk_tokens), N = fixed budget,
        # 0/None = legacy whole-prompt prefill with layer interruption
        self.chunked = chunk_tokens not in (None, 0, "0")
        self.chunk_budget = (None if chunk_tokens == "auto"
                             else int(chunk_tokens) if self.chunked else 0)
        # multi-step decode horizons: "auto" = roofline-chosen K per round
        # (PerfModel.suggest_decode_horizon under the §3.4.1 preemption
        # bound), N = fixed K, 1/0/None = today's one-sync-per-token decode
        # (which CoLocatedServer pins). Strict rounds and any round with a
        # queued/resident online request always clamp to K=1.
        self.horizon_req = ("auto" if decode_horizon == "auto"
                           else max(int(decode_horizon), 1)
                           if decode_horizon not in (None, 0, "0") else 1)
        self.max_horizon = max_horizon
        self.clock = clock or WallClock()
        self.slo_ttft = slo_ttft
        self.slo_tpot = slo_tpot
        self.pm = PerfModel(cfg, hw or cpu_measured())
        self.rng = random.Random(seed)
        self.seed = seed
        self.relaxed_decode_cap = relaxed_decode_cap
        self.gating_horizon = gating_horizon
        # cross-request KV reuse (radix prefix cache + refcounted COW
        # pages); only effective on the chunked-prefill path — the legacy
        # layer-interruptible path rewrites whole tables and cannot share
        self.prefix_cache = bool(prefix_cache) and self.chunked
        if model is None:
            model = build_model(cfg, remat=False)
            params = model.init(jax.random.PRNGKey(seed))
        self.model, self.params = model, params
        # engines in (and across) runtimes over the same weights share one
        # compiled-kernel set; pass runtime.kernel_donor to the next runtime
        donor: ServingEngine | None = kernels_from
        self.strict_pool: list[EngineSlot] = []
        self.relaxed_pool: list[EngineSlot] = []
        for i in range(n_strict):
            eng = ServingEngine(model, params, num_pages=num_pages,
                                page_size=page_size, decode_buckets=decode_buckets,
                                backend=backend, prefix_cache=self.prefix_cache,
                                kernels_from=donor)
            donor = donor or eng
            self.strict_pool.append(EngineSlot(f"strict{i}", "strict", eng))
        for i in range(n_relaxed):
            eng = ServingEngine(model, params, num_pages=num_pages,
                                page_size=page_size, decode_buckets=decode_buckets,
                                backend=backend, prefix_cache=self.prefix_cache,
                                kernels_from=donor)
            self.relaxed_pool.append(EngineSlot(f"relaxed{i}", "relaxed", eng))
        self.kernel_donor = donor  # share compiled kernels across runtimes
        # queues hold (req, tokens[, home_slot]) — home pins a layer-
        # interrupted prefill to the engine holding its partial state
        self.online_queue: list[tuple[Request, list[int]]] = []
        self.offline_queue: list[tuple[Request, list[int], EngineSlot | None]] = []
        self.finished: list[Request] = []
        self.all_requests: list[Request] = []
        # prefilled offline waiting for strict-pool capacity (baselines);
        # their KV stays on the source relaxed engine until a slot frees
        self.place_queue: list[tuple[Request, EngineSlot]] = []
        self.tokens: dict[int, list[int]] = {}   # rid -> final token stream
        self.metrics = Metrics()
        self.measured_tpot = slo_tpot / 4
        self._op_cap: int | None = None
        self._push_cost = 0.0   # per-round push-migration transfer (overlap)
        # wall-mode live-arrival probe for §3.4.1 (run() wires the trace feed)
        self.incoming_online = lambda: False
        self._next_online_arrival = lambda: None
        # ---- fault tolerance (chaos replay) ----
        plan = FaultPlan.parse(fault_plan)
        self.injector = (FaultInjector(plan, chaos_seed)
                         if plan is not None and plan.events else None)
        self.chaos_seed = chaos_seed
        self.max_transfer_attempts = max_transfer_attempts
        self.backoff_base = backoff_base
        self.watchdog_mult = watchdog_mult
        self.max_offline_backlog = max_offline_backlog
        self.max_online_queue = max_online_queue
        # frontend request log: prompts survive engine crashes, so recovery
        # re-admits from here instead of reading dead-engine memory
        self.prompts: dict[int, list[int]] = {}
        self.shed: list[Request] = []
        self.dead_pool: list[EngineSlot] = []
        self._page_leases: list[tuple[EngineSlot, list[int], float]] = []
        self._admission = "admit"
        # ---- live-serving lifecycle (gateway / PR 9) ----
        self.by_rid: dict[int, Request] = {}   # every accepted submit, ever
        self.cancelled: list[Request] = []     # terminal: client or deadline
        self.rejected: list[Request] = []      # bounced at submit (terminal)
        self._deadline_watch: list[Request] = []
        self.draining = False   # graceful shutdown: finish residents, no SLA
                                # change — only the `drained` counter

    # ------------------------------------------------------------------
    # submission + one co-located round
    # ------------------------------------------------------------------
    def submit(self, req: Request, tokens: list[int]) -> None:
        """Accept a request into the frontend queues.

        Validates up front — a malformed submit must fail HERE with a clear
        error, not corrupt queue/engine state rounds later: empty prompts
        would underflow the chunk scheduler, a length mismatch would trip an
        engine assert mid-prefill, and a duplicate rid would silently alias
        two requests' KV tables and token buffers. Online submits are
        additionally bounded by ``max_online_queue`` (``AdmissionRejected``
        — backpressure the caller sees synchronously)."""
        if not tokens:
            raise ValueError(f"submit of rid {req.rid}: empty token list "
                             "(prompts must contain >= 1 token)")
        if len(tokens) != req.prompt_len:
            raise ValueError(
                f"submit of rid {req.rid}: prompt_len={req.prompt_len} but "
                f"{len(tokens)} tokens were provided")
        if req.rid in self.by_rid:
            raise ValueError(
                f"submit of duplicate rid {req.rid} "
                f"({self.by_rid[req.rid].phase.value}): rids are unique per "
                "runtime; resubmission would alias KV tables")
        if (req.kind == Kind.ONLINE and self.max_online_queue is not None
                and len(self.online_queue) >= self.max_online_queue):
            self.metrics.rejected_online += 1
            req.phase = Phase.CANCELLED
            req.cancel_reason = "rejected"
            self.rejected.append(req)
            raise AdmissionRejected(
                f"online admission queue full "
                f"({len(self.online_queue)}/{self.max_online_queue}); "
                "retry later or shed load upstream")
        self.by_rid[req.rid] = req
        self.all_requests.append(req)
        self.prompts[req.rid] = list(tokens)
        if req.ttft_deadline is not None or req.total_deadline is not None:
            self._deadline_watch.append(req)
        if req.kind == Kind.ONLINE:
            self.online_queue.append((req, tokens))
        else:
            self.offline_queue.append((req, tokens, None))

    def step(self) -> bool:
        """One scheduling round across every pool. Returns True if any
        engine did work; virtual mode advances the clock by the modeled
        round duration (max across engines — pools run in parallel)."""
        now = self.clock.now()
        self._enforce_deadlines(now)
        self._apply_faults(now)
        self._admission = self._admission_state()
        if self._admission != "admit":
            self.metrics.degraded_rounds += 1
            if self._admission == "shed":
                self._shed_offline()
        self._retry_placements()
        costs = [self._relaxed_round(slot, now) for slot in self.relaxed_pool]
        costs += [self._strict_round(slot, now) for slot in self.strict_pool]
        self.metrics.rounds += 1
        cost = max(costs, default=0.0)  # pools can crash away entirely
        if cost > 0:
            self.clock.advance(cost)
            return True
        return False

    # ------------------------------------------------------------------
    # live request lifecycle: cancel, deadlines, streaming, health, drain
    # ------------------------------------------------------------------
    def cancel(self, rid: int, *, reason: str = "client") -> Request:
        """Abort a request at ANY lifecycle stage — queued, mid-chunked-
        prefill, mid-decode, parked mid-migration — releasing every KV page
        and refcount it held on every engine. Terminal and final: a
        cancelled request is never re-admitted and bills no recompute waste
        (nothing will re-run). Raises ``ValueError`` for unknown rids and
        for requests already in a terminal state, so double-cancels and
        cancel-after-finish are caller bugs, not silent no-ops."""
        req = self.by_rid.get(rid)
        if req is None:
            raise ValueError(f"cancel of unknown rid {rid}: never submitted "
                             "to this runtime (or rejected at admission)")
        if req.phase is Phase.FINISHED:
            raise ValueError(f"cancel of rid {rid}: already finished")
        if req.phase is Phase.CANCELLED:
            raise ValueError(f"cancel of rid {rid}: already cancelled "
                             f"({req.cancel_reason})")
        self._purge(req)
        req.phase = Phase.CANCELLED
        req.cancel_reason = reason
        req.finish_time = self.clock.now()
        self.cancelled.append(req)
        if reason == "deadline":
            self.metrics.deadline_aborts += 1
        else:
            self.metrics.cancelled += 1
        return req

    def _purge(self, req: Request) -> None:
        """Remove every trace of a live request from the cluster: frontend
        queues, slot resident lists, pinned prefills, parked placements,
        and per-engine state/pages (``ServingEngine.release`` is idempotent
        and stage-agnostic, so sweeping every slot is safe)."""
        rid = req.rid
        self.online_queue[:] = [e for e in self.online_queue
                                if e[0].rid != rid]
        self.offline_queue[:] = [e for e in self.offline_queue
                                 if e[0].rid != rid]
        self.place_queue[:] = [e for e in self.place_queue
                               if e[0].rid != rid]
        self._deadline_watch[:] = [r for r in self._deadline_watch
                                   if r.rid != rid]
        for slot in self.strict_pool + self.relaxed_pool:
            slot.prefilling[:] = [e for e in slot.prefilling
                                  if e[0].rid != rid]
            slot.online[:] = [r for r in slot.online if r.rid != rid]
            slot.offline[:] = [r for r in slot.offline if r.rid != rid]
            slot.engine.release(rid)
        self.prompts.pop(rid, None)   # cancelled work is never recovered

    def _enforce_deadlines(self, now: float) -> None:
        """Abort watched requests whose TTFT/total deadline has passed
        (``core.scheduling.deadline_state``). Runs at the top of every
        round, BEFORE admission/prefill — a blown request must not steal
        another FLOP from requests that can still meet their SLOs. Aborts
        count in ``deadline_aborts`` and are billed as SLO violations in
        ``summary()``, never as attainment."""
        if not self._deadline_watch:
            return
        for req in list(self._deadline_watch):
            if req.phase in (Phase.FINISHED, Phase.CANCELLED) or req.done:
                self._deadline_watch.remove(req)
                continue
            if sch.deadline_state(req, now) != "ok":
                self.cancel(req.rid, reason="deadline")  # unwatches via purge

    def generated_tokens(self, rid: int) -> list[int]:
        """Output tokens produced so far for ``rid`` — the gateway's
        streaming poll. Reads the resident engine's token ring (finished
        requests read the frontend copy), clamped to ``req.generated`` so
        eviction/crash recovery is invisible to the stream: greedy replay
        regenerates bit-identical tokens, and until progress catches back
        up to the client's emit offset the poll simply returns a prefix it
        has already seen. Empty for unknown/rejected rids."""
        req = self.by_rid.get(rid)
        if req is None:
            return []
        final = self.tokens.get(rid)
        if final is not None:
            return final[req.prompt_len:]
        if req.generated <= 0:
            return []
        for slot in self.strict_pool + self.relaxed_pool:
            buf = slot.engine.token_buf.get(rid)
            if buf is not None:
                return buf[req.prompt_len: req.prompt_len + req.generated]
        return []

    def health(self) -> dict:
        """Cluster health probe for the gateway's ``/healthz``: per-slot
        liveness and page occupancy plus the PR 6 crash/watchdog counters.
        ``status`` is ``"ok"`` (full topology), ``"degraded"`` (crashed
        engines or a promoted/emptied pool — still serving), or ``"dead"``
        (no live engine; nothing can be served)."""
        slots = []
        for s in self.strict_pool + self.relaxed_pool + self.dead_pool:
            eng = s.engine
            slots.append({
                "name": s.name,
                "role": s.role,
                "alive": eng.alive,
                "resident": s.resident,
                "prefilling": len(s.prefilling),
                "free_pages": eng.cache.allocator.free_pages if eng.alive else 0,
                "live_pages": eng.cache.allocator.live_pages if eng.alive else 0,
            })
        n_live = len(self.strict_pool) + len(self.relaxed_pool)
        if n_live == 0:
            status = "dead"
        elif (self.dead_pool or not self.strict_pool
              or not self.relaxed_pool):
            status = "degraded"
        else:
            status = "ok"
        return {
            "status": status,
            "draining": self.draining,
            "engines": slots,
            "queued_online": len(self.online_queue),
            "queued_offline": len(self.offline_queue),
            "engine_crashes": self.metrics.engine_crashes,
            "watchdog_aborts": self.metrics.watchdog_aborts,
            "promotions": self.metrics.promotions,
            "degraded_rounds": self.metrics.degraded_rounds,
        }

    def live_pages(self) -> dict[str, int]:
        """Allocator-held pages per live engine — the drain-time leak
        probe: after a graceful drain releases residents, leases, and the
        prefix trees, every count here must be zero."""
        return {s.name: s.engine.cache.allocator.live_pages
                for s in self.strict_pool + self.relaxed_pool}

    def release_retained(self) -> int:
        """Final step of a graceful drain: return pages that are held on
        purpose rather than by an in-flight request — outstanding fault-
        injection page leases and the radix prefix trees' own references
        (``release_all``: a decref per node, unlike the crash path's
        ``clear``). Returns the number of page references released; after
        this, any nonzero ``live_pages()`` entry is a genuine leak."""
        released = 0
        for lease in list(self._page_leases):
            slot, pages, _ = lease
            self._page_leases.remove(lease)
            if slot.engine.alive:
                slot.engine.cache.allocator.free(pages)
                released += len(pages)
        for s in self.strict_pool + self.relaxed_pool:
            if s.engine.cache.prefix is not None:
                released += s.engine.cache.prefix.release_all()
        return released

    # ------------------------------------------------------------------
    # fault injection + recovery (chaos replay)
    # ------------------------------------------------------------------
    def _slot_named(self, name: str) -> EngineSlot | None:
        for s in self.strict_pool + self.relaxed_pool:
            if s.name == name:
                return s
        return None

    def _apply_faults(self, now: float) -> None:
        """Round-boundary fault dispatch: crash engines, leak (and later
        restore) pool pages. Every decision comes from the seeded plan, so
        a chaos replay is exactly as deterministic as a clean one."""
        for lease in list(self._page_leases):
            slot, pages, until = lease
            if now >= until:
                self._page_leases.remove(lease)
                if slot.engine.alive:
                    slot.engine.cache.allocator.free(pages)
        if self.injector is None:
            return
        for name in self.injector.crashes_due(now):
            slot = self._slot_named(name)
            if slot is not None:
                self._crash_engine(slot)
        for ev in self.injector.leaks_due(now):
            slot = self._slot_named(ev.engine)
            if slot is None:
                continue
            alloc = slot.engine.cache.allocator
            pages = alloc.alloc(min(ev.pages, alloc.free_pages))
            if ev.duration > 0:
                self._page_leases.append((slot, pages, now + ev.duration))

    def _crash_engine(self, slot: EngineSlot) -> None:
        """Engine-process crash: device KV and host bookkeeping are gone.
        Every in-flight request is re-admitted from the frontend prompt log
        through the recompute path — greedy streams regenerate bit-identical
        tokens, so recovery preserves token parity. A crashed strict engine
        additionally promotes a drained relaxed engine so online traffic
        never loses its pool."""
        lost: dict[int, Request] = {}
        for r in slot.online + slot.offline:
            if not r.done:
                lost[r.rid] = r
        for entry in slot.prefilling:
            if not entry[0].done:
                lost[entry[0].rid] = entry[0]
        for entry in list(self.place_queue):
            if entry[1] is slot:
                self.place_queue.remove(entry)
                if not entry[0].done:
                    lost[entry[0].rid] = entry[0]
        for entry in list(self.offline_queue):
            if entry[2] is slot:       # home-pinned resume: state is gone
                self.offline_queue.remove(entry)
                if not entry[0].done:
                    lost[entry[0].rid] = entry[0]
        slot.engine.crash()
        slot.online.clear()
        slot.offline.clear()
        slot.prefilling.clear()
        pool = self.strict_pool if slot.role == "strict" else self.relaxed_pool
        pool.remove(slot)
        self.dead_pool.append(slot)
        self.metrics.engine_crashes += 1
        if slot.role == "strict":
            self._promote_relaxed()
        for r in sorted(lost.values(), key=lambda r: (r.arrival, r.rid)):
            self._readmit(r)
            self.metrics.recoveries += 1

    def _readmit(self, req: Request) -> None:
        """Requeue a request whose engine-side state is gone (crash, or
        exhausted migration retries): reset progress, charge the recompute
        waste, keep SLO-relevant timestamps. Greedy decoding is batch- and
        chunk-independent (the invariant the eviction path already relies
        on), so the regenerated stream is bit-identical to the lost one."""
        # prefix-cache claims were page-table updates, not compute — losing
        # them wastes nothing, so they never count as recompute
        if req.generated > 0:
            req.recompute_tokens += req.context_len - req.cached_tokens
        elif req.prefill_tokens_done > 0:
            req.recompute_tokens += (req.prefill_tokens_done
                                     - req.cached_tokens)
        elif req.prefill_layers_done > 0:
            req.recompute_tokens += req.prompt_len
        req.generated = 0
        req.prefill_layers_done = 0
        req.prefill_tokens_done = 0
        req.cached_tokens = 0
        req.phase = Phase.QUEUED
        toks = self.prompts[req.rid]
        if req.kind == Kind.ONLINE:
            self.online_queue.append((req, toks))
            self.online_queue.sort(key=lambda e: (e[0].arrival, e[0].rid))
        else:
            self.offline_queue.append((req, toks, None))

    def _promote_relaxed(self) -> None:
        """Strict failover: flip the most-drained relaxed engine to the
        strict role. Its decoding residents and landed KV move with it;
        in-flight prefills are aborted back to the queues (recompute),
        because strict rounds only run the prefill path once the relaxed
        pool is empty."""
        if not self.relaxed_pool:
            return
        slot = min(self.relaxed_pool,
                   key=lambda s: (sum(s.engine.cache.lengths.values()),
                                  s.name))
        self.relaxed_pool.remove(slot)
        for entry in list(slot.prefilling):
            self._abort_chunk_prefill(slot, entry)
        for idx, entry in enumerate(self.offline_queue):
            req, toks, home = entry
            if home is slot:           # layer-partial resume: unpin it
                slot.engine.abort_prefill(req.rid)
                slot.engine.requests.pop(req.rid, None)
                slot.engine.token_buf.pop(req.rid, None)
                self.offline_queue[idx] = (req, toks, None)
        slot.role = "strict"
        self.strict_pool.append(slot)
        self.metrics.promotions += 1

    def _admission_state(self) -> str:
        """Per-round graceful-degradation decision (``core.scheduling``):
        under overload, fresh offline admission stops first ("defer");
        only with ``max_offline_backlog`` configured is excess offline
        queue shed. Online work is never deferred or shed."""
        pools = self.relaxed_pool or self.strict_pool
        free = min((s.engine.cache.available_pages
                    / s.engine.cache.num_pages for s in pools), default=0.0)
        return sch.admission_decision(
            queued_online=len(self.online_queue),
            strict_pressure=max((s.pressure for s in self.strict_pool),
                                default=0.0),
            offline_backlog=len(self.offline_queue),
            free_page_frac=free,
            max_backlog=self.max_offline_backlog)

    def _shed_offline(self) -> None:
        """Shed the newest fresh offline entries beyond the bounded
        backlog. Sheds are surfaced (``summary()['shed_requests']``,
        ``self.shed``) — never silent."""
        excess = len(self.offline_queue) - (self.max_offline_backlog or 0)
        for i in range(len(self.offline_queue) - 1, -1, -1):
            if excess <= 0:
                break
            if self.offline_queue[i][2] is not None:
                continue               # pinned resumes hold pages; keep them
            req, _, _ = self.offline_queue.pop(i)
            self.shed.append(req)
            self.metrics.shed_requests += 1
            excess -= 1

    # ------------------------------------------------------------------
    # relaxed pool: prefill (layer-interruptible) + offline decode
    # ------------------------------------------------------------------
    def _relaxed_round(self, slot: EngineSlot, now: float) -> float:
        self._push_cost = 0.0
        if self.chunked:
            # fused mixed round: the §3.4.1 boundary is the chunk, chosen
            # here — deterministic under both clocks, no mid-layer polling
            pf = self._pick_chunk_prefill(slot)
            cost = self._decode_slot(slot, now, relaxed=True, prefill=pf)
        else:
            cost = self._prefill_one(slot, now)
            if slot.online or (self.policy == "ooco" and slot.offline):
                cost += self._decode_slot(slot, now + cost, relaxed=True)
        # push-migration KV transfers ride the interconnect while this
        # round's compute occupies the chips, so the round is charged
        # max(compute, transfer), not the sum — the same overlap the
        # §3.4.3 pull path models (deterministic: both terms are modeled)
        return max(cost, self._push_cost)

    # ------------------------------------------------------------------
    # chunk-granular prefill selection (token-budget scheduling)
    # ------------------------------------------------------------------
    def _pick_chunk_prefill(self, slot: EngineSlot):
        """Choose the prefill request this slot advances this round. §3.4.1
        fast preemption happens HERE, at a deterministic chunk boundary
        under both clocks: under ``ooco`` a queued online request pauses an
        in-progress offline prefill (the offline keeps its landed KV and
        resumes later without re-running any layer); ``online_priority``
        starts online work first but never pauses in-flight prefills
        (legacy semantics: preemption is an ooco mechanism); ``base_pd``
        keeps strict FIFO — its head-of-line blocking is the point of the
        baseline. Returns ``(req, toks)`` or None."""
        prog = slot.prefilling
        prog[:] = [e for e in prog if not e[0].done
                   and e[0].rid in slot.engine.requests]
        if self.policy == "base_pd":
            return prog[0] if prog else self._admit_prefill_fifo(slot)
        cur_online = next((e for e in prog if e[0].kind == Kind.ONLINE), None)
        if cur_online is not None:
            return cur_online
        if self.policy == "ooco" and self.online_queue:
            entry = self._admit_online_prefill(slot)
            if entry is not None:
                if prog:
                    self.metrics.chunk_preemptions += 1
                prog.insert(0, entry)
                return entry
        if prog:
            return prog[0]
        if self.online_queue:
            entry = self._admit_online_prefill(slot)
            if entry is not None:
                prog.append(entry)
                return entry
        entry = self._next_offline_for(slot)
        if entry is not None:
            req, toks, home = entry
            if home is None:
                slot.engine.add_request(req, toks)
                slot.engine.claim_prefix(req.rid)
            prog.append((req, toks))
            return (req, toks)
        return None

    def _admit_online_prefill(self, slot: EngineSlot):
        """Pop + admit the online queue head (evicting offline residents for
        space, as in the legacy path). None if it cannot fit."""
        eng = slot.engine
        req, toks = self.online_queue[0]
        if not eng.cache.can_fit(len(toks)):
            need = (eng.cache.pages_for(len(toks))
                    - eng.cache.available_pages) * eng.cache.page_size
            self._evict_from(slot, need)
        if not eng.cache.can_fit(len(toks)):
            return None
        self.online_queue.pop(0)
        eng.add_request(req, toks)
        eng.claim_prefix(req.rid)
        return (req, toks)

    def _admit_prefill_fifo(self, slot: EngineSlot):
        """base_pd admission: plain FIFO over both queues by arrival."""
        if (self.offline_queue
                and (not self.online_queue
                     or self.offline_queue[0][0].arrival
                     < self.online_queue[0][0].arrival)):
            entry = self._next_offline_for(slot)
            if entry is not None:
                req, toks, home = entry
                if home is None:
                    slot.engine.add_request(req, toks)
                    slot.engine.claim_prefix(req.rid)
                slot.prefilling.append((req, toks))
                return (req, toks)
        if self.online_queue:
            entry = self._admit_online_prefill(slot)
            if entry is not None:
                slot.prefilling.append(entry)
                return entry
        return None

    def _plan_round(self, slot: EngineSlot, relaxed: bool,
                    pf_req: Request | None) -> sch.MixedPlan:
        """Token-budget plan for one round (decode batch + prefill chunk).
        ooco routes decode through §3.4.4 mix-decoding inside the
        scheduler; the baselines keep their legacy decode selection and the
        budget only sizes the chunk."""
        remaining = (pf_req.prompt_len - pf_req.prefill_tokens_done
                     if pf_req is not None else 0)
        horizon = self._horizon_allowance(relaxed)
        if self.policy == "ooco":
            slo = (None if relaxed
                   else self._effective_slo(slot.online, slot.offline))
            return sch.token_budget_schedule(
                slot.online, slot.offline, pf_req, remaining, self.pm,
                slo=slo, budget_tokens=self.chunk_budget or None,
                relaxed_cap=self.relaxed_decode_cap,
                mem_budget_bytes=None if relaxed else self._pool_kv_bytes(slot),
                rng=self.rng, horizon=horizon)
        decode = self._select_batch(slot, relaxed)
        return sch.token_budget_schedule(
            slot.online, slot.offline, pf_req, remaining, self.pm,
            slo=None, budget_tokens=self.chunk_budget or None,
            relaxed_cap=self.relaxed_decode_cap, decode_override=decode,
            horizon=horizon)

    def _horizon_allowance(self, relaxed: bool) -> int:
        """Upper bound on this round's decode horizon before the per-round
        §3.4.1 clamp (``sch.decode_horizon_steps``) refines it."""
        if not relaxed or self.horizon_req == 1:
            return 1
        return (self.max_horizon if self.horizon_req == "auto"
                else min(self.horizon_req, self.max_horizon))

    def _choose_horizon(self, slot: EngineSlot, batch: list[Request],
                        allowance: int) -> int:
        """Per-round K: the §3.4.1-aware clamp (queued/resident online work
        forces K=1), the roofline choice for "auto", then the engine's page
        claim-ahead capacity."""
        if allowance <= 1 or not batch:
            return 1
        k = sch.decode_horizon_steps(
            batch, self.pm, requested=self.horizon_req,
            queued_online=bool(self.online_queue) or bool(self.incoming_online()),
            preempt_latency=0.25 * self.slo_ttft,
            max_horizon=allowance)
        if k > 1:
            k = slot.engine.max_horizon_for([r.rid for r in batch], k)
        return k

    def _choose_mixed_horizon(self, slot: EngineSlot, batch: list[Request],
                              pf_req: Request, chunk: int,
                              allowance: int) -> int:
        """Per-round K for a fused mixed round (chunk + decode in one
        scan). Online work anywhere in the dispatch forces K=1 — the
        §3.4.1 preemption boundary must stay a chunk boundary when latency
        is critical. Otherwise the roofline choice under the preemption
        bound (halved when online arrivals are already queued, so K
        shrinks rather than pinning — the chunk has to land either way),
        then the engine's combined chunk + decode page claim-ahead."""
        if allowance <= 1 or not batch:
            return 1   # splitting a chunk with no decode riding is waste
        if pf_req.kind is Kind.ONLINE or any(r.kind is Kind.ONLINE
                                             for r in batch):
            return 1
        queued = bool(self.online_queue) or bool(self.incoming_online())
        if self.horizon_req == "auto":
            k = self.pm.suggest_mixed_horizon(
                chunk, pf_req.prefill_tokens_done + chunk,
                [r.context_len for r in batch],
                preempt_latency=0.25 * self.slo_ttft,
                queued_online=queued, max_horizon=allowance)
        else:
            k = allowance
        k = min(k, chunk)
        if k > 1:
            k = slot.engine.max_mixed_horizon_for(
                [r.rid for r in batch], pf_req.rid, chunk, k)
        return max(k, 1)

    def _after_chunk(self, slot: EngineSlot, req: Request, now: float,
                     step_lat: float) -> float:
        """Post-chunk bookkeeping; returns any extra cost (placement)."""
        self.metrics.chunks += 1
        if req.prefill_tokens_done < req.prompt_len:
            return 0.0                       # mid-prefill: stays pinned
        slot.prefilling[:] = [e for e in slot.prefilling if e[0] is not req]
        if req.first_token_time is None:
            req.first_token_time = now + step_lat
        eng = slot.engine
        if req.done:
            eng.cache.free(req.rid)
            self._finish(req, eng, now + step_lat)
            return 0.0
        if self.policy == "ooco" and req.kind != Kind.ONLINE:
            slot.offline.append(req)         # decode on relaxed until pulled
            return 0.0
        # push transfer overlaps the source round's compute (charged as
        # max at the round level, not summed here)
        self._push_cost += self._place_on_strict(req, slot)
        return 0.0

    def _prefill_cost(self, est_latency: float, layers_run: int,
                      measured: float) -> float:
        if not self.clock.virtual:
            return measured
        return est_latency * layers_run / max(self.cfg.num_layers, 1)

    def _prefill_one(self, slot: EngineSlot, now: float) -> float:
        eng = slot.engine
        if (self.policy == "base_pd" and self.offline_queue
                and (not self.online_queue
                     or self.offline_queue[0][0].arrival
                     < self.online_queue[0][0].arrival)):
            # base_pd has no online/offline distinction at prefill: plain
            # FIFO, so offline prefills head-of-line block online TTFT
            return self._prefill_offline(slot, now)
        if self.online_queue:
            req, toks = self.online_queue.pop(0)
            if not eng.cache.can_fit(len(toks)):
                need = (eng.cache.pages_for(len(toks))
                        - eng.cache.available_pages) * eng.cache.page_size
                self._evict_from(slot, need)
            if not eng.cache.can_fit(len(toks)):
                self.online_queue.insert(0, (req, toks))
                return 0.0
            eng.add_request(req, toks)
            est = self.pm.prefill_estimate([len(toks)]).latency
            t0 = time.perf_counter()
            eng.prefill(req.rid)
            cost = self._prefill_cost(est, self.cfg.num_layers,
                                      time.perf_counter() - t0)
            self.metrics.prefill_modeled_seconds += cost
            if req.first_token_time is None:
                req.first_token_time = now + cost
            if req.done:
                eng.cache.free(req.rid)
                self._finish(req, eng, now + cost)
                return cost
            self._push_cost += self._place_on_strict(req, slot)
            return cost
        return self._prefill_offline(slot, now)

    def _prefill_offline(self, slot: EngineSlot, now: float) -> float:
        eng = slot.engine
        entry = self._next_offline_for(slot)
        if entry is None:
            return 0.0
        req, toks, home = entry
        if home is None:
            eng.add_request(req, toks)
        est = self.pm.prefill_estimate([len(toks)]).latency
        preempt = self._preempt_probe(slot, now, est) \
            if self.policy == "ooco" else None
        layers_before = req.prefill_layers_done
        t0 = time.perf_counter()
        status = eng.prefill(req.rid, should_preempt=preempt)
        cost = self._prefill_cost(est, req.prefill_layers_done - layers_before,
                                  time.perf_counter() - t0)
        self.metrics.prefill_modeled_seconds += cost
        if status == "preempted":
            req.phase = Phase.QUEUED
            self.offline_queue.insert(0, (req, toks, slot))
            return cost
        if req.first_token_time is None:
            req.first_token_time = now + cost
        if req.done:
            eng.cache.free(req.rid)
            self._finish(req, eng, now + cost)
            return cost
        if self.policy == "ooco":
            slot.offline.append(req)     # decode on relaxed until pulled
        else:
            self._push_cost += self._place_on_strict(req, slot)
        return cost

    def _next_offline_for(self, slot: EngineSlot):
        """First admissible offline queue entry for this engine: resumes are
        pinned to the engine holding the partial state; fresh prefills must
        fit and (ooco) pass the §3.4.2 gating cost model. Bounded FIFO scan."""
        eng = slot.engine
        scanned = 0
        for entry in list(self.offline_queue):
            req, toks, home = entry
            if home is not None and home is not slot:
                continue
            if home is None and self._admission != "admit":
                # degraded round: fresh offline work stays queued; pinned
                # resumes keep going (finishing them frees pages)
                continue
            scanned += 1
            if scanned > 4:
                break
            if home is None:
                if not eng.cache.can_fit(len(toks)):
                    continue
                if self.policy == "ooco" and req.prefill_layers_done == 0:
                    budget = self._free_kv_bytes(slot)
                    cached = 0
                    if (eng.cache.prefix is not None
                            and req.prefill_tokens_done == 0):
                        # planning peek, not a claim: how much of this
                        # prompt the prefix cache would serve (touch=False
                        # keeps the LRU order unperturbed by rejections)
                        _, cached = eng.cache.prefix.match(
                            toks, limit=len(toks) - 1, touch=False)
                    ok = sch.gating_decision(
                        req, slot.offline, self.pm,
                        evict_probability=self._evict_probability(),
                        horizon_seconds=self.gating_horizon,
                        mem_budget_bytes=budget,
                        cached_tokens=cached)
                    if not ok:
                        continue
            self.offline_queue.remove(entry)
            return entry
        return None

    def _preempt_probe(self, slot: EngineSlot, now: float, est_latency: float):
        """§3.4.1 layer-level interruption predicate. Wall mode polls the
        live queue/arrival feed; virtual mode interrupts at the first layer
        boundary past the next online arrival's timestamp (deterministic)."""
        if not self.clock.virtual:
            return lambda: bool(self.online_queue) or self.incoming_online()
        layer_dt = est_latency / max(self.cfg.num_layers, 1)
        nxt = self._next_online_arrival()
        polls = [0]

        def probe() -> bool:
            polls[0] += 1
            if self.online_queue:
                return True
            boundary = now + polls[0] * layer_dt
            return nxt is not None and nxt <= boundary

        return probe

    # ------------------------------------------------------------------
    # placement, migration, eviction (bottleneck-guided, §3.4.1/§3.4.3)
    # ------------------------------------------------------------------
    def _free_kv_bytes(self, slot: EngineSlot) -> float:
        cache = slot.engine.cache
        return (cache.available_pages * cache.page_size
                * max(self.pm.kv_bytes_per_token(), 1.0))

    def _pool_kv_bytes(self, slot: EngineSlot) -> float:
        cache = slot.engine.cache
        return (cache.num_pages * cache.page_size
                * max(self.pm.kv_bytes_per_token(), 1.0))

    def _place_on_strict(self, req: Request, src: EngineSlot) -> float:
        """Push a prefilled request to the strict pool (most free KV pages
        wins), evicting offline victims on the destination if needed. If no
        strict engine can hold it even after eviction — or the source IS a
        strict engine (degraded mode after failover) — it decodes in place
        on the source engine (never dropped)."""
        if not self.strict_pool or src in self.strict_pool:
            (src.online if req.kind == Kind.ONLINE
             else src.offline).append(req)
            return 0.0
        n = src.engine.cache.lengths[req.rid]
        dst = max(self.strict_pool,
                  key=lambda s: s.engine.cache.available_pages)
        if not dst.engine.cache.can_fit(n) and req.kind == Kind.ONLINE:
            # only online work may evict offline victims to claim space
            need = (dst.engine.cache.pages_for(n)
                    - dst.engine.cache.available_pages) \
                * dst.engine.cache.page_size
            self._evict_from(dst, need)
        if not dst.engine.cache.can_fit(n):
            if req.kind == Kind.ONLINE:
                src.online.append(req)   # decode in place, never dropped
            else:
                self.place_queue.append((req, src))
            return 0.0
        return self._migrate(req, src, dst)

    def _retry_placements(self) -> None:
        """Drain parked offline placements as strict capacity frees up."""
        if not self.strict_pool:
            return
        for entry in list(self.place_queue):
            req, src = entry
            if req.done:
                self.place_queue.remove(entry)
                continue
            dst = max(self.strict_pool,
                      key=lambda s: s.engine.cache.available_pages)
            if dst.engine.cache.can_fit(src.engine.cache.lengths[req.rid]):
                self.place_queue.remove(entry)
                self._migrate(req, src, dst)

    def _migrate(self, req: Request, src: EngineSlot, dst: EngineSlot) -> float:
        """Real KV movement between engines (RDMA->ICI analogue), retry-
        safe: the payload is exported with an integrity checksum while the
        source keeps its pages; each attempt may be failed or corrupted by
        the fault injector; failures retry with seeded exponential backoff
        charged to the virtual clock; and when the attempt budget is
        exhausted the request falls back to the recompute path (re-admitted
        from the prompt log) instead of being lost mid-transfer."""
        eng = src.engine
        k, v, n, checksum = eng.export_for_transfer(req.rid)
        per_attempt = (self.pm.migration_seconds(req.context_len)
                       if self.clock.virtual else 0.0)
        cost = 0.0
        for attempt in range(1, self.max_transfer_attempts + 1):
            outcome = ("ok" if self.injector is None
                       else self.injector.transfer_outcome(self.clock.now()))
            cost += per_attempt
            if outcome == "ok":
                dst.engine.migrate_in(
                    req.rid, req, eng.token_buf[req.rid], k, v, n,
                    sampling=eng.req_sampling.pop(req.rid, None),
                    checksum=checksum)
                eng.commit_transfer_out(req.rid)
                (dst.online if req.kind == Kind.ONLINE
                 else dst.offline).append(req)
                self.metrics.migrations += 1
                return cost
            if outcome == "corrupt":
                # payload arrives bit-flipped: the destination checksum
                # rejects it before any state lands, so the intact source
                # copy simply re-sends
                bad = np.array(k, copy=True)
                bad.flat[0] = abs(bad.flat[0]) + 1.0
                try:
                    dst.engine.migrate_in(
                        req.rid, req, eng.token_buf[req.rid], bad, v, n,
                        sampling=eng.req_sampling.get(req.rid),
                        checksum=checksum)
                    raise AssertionError("corrupt transfer went undetected")
                except TransferIntegrityError:
                    pass
            self.metrics.migration_retries += 1
            if attempt < self.max_transfer_attempts:
                delay = self.injector.backoff_seconds(
                    attempt, self.backoff_base)
                if self.clock.virtual:
                    cost += delay
        # attempt budget exhausted: recompute fallback — release the source
        # copy and re-admit from the frontend prompt log (greedy replay
        # regenerates the same tokens; waste lands in recompute_tokens)
        eng.cache.free(req.rid)
        eng.requests.pop(req.rid, None)
        eng.token_buf.pop(req.rid, None)
        eng.req_sampling.pop(req.rid, None)
        self.metrics.migration_recomputes += 1
        self._readmit(req)
        return cost

    def _evict_from(self, slot: EngineSlot, need_tokens: float,
                    exclude: set[int] | None = None) -> None:
        """§3.4.1 bottleneck-aware victim selection on a real engine: free
        >= need_tokens of KV by evicting offline decodes (recompute later)."""
        if need_tokens <= 0:
            return
        exclude = exclude or set()
        candidates = [r for r in slot.offline if r.rid not in exclude]
        # refcount-aware ranking: a victim frees only its UNSHARED pages
        # (prefix-cache siblings keep theirs), so prefer unshared requests
        # and never pick one that would free nothing
        shared = {r.rid: slot.engine.cache.shared_tokens(r.rid)
                  for r in candidates} if self.prefix_cache else None
        victims = sch.select_eviction_victims(
            candidates, int(np.ceil(need_tokens)), slot.last_bottleneck,
            shared_tokens=shared)
        eng = slot.engine
        for r in victims:
            slot.offline.remove(r)
            toks = eng.token_buf[r.rid][: r.prompt_len]
            eng.evict(r.rid)       # frees pages, counts recompute_tokens
            eng.requests.pop(r.rid, None)
            eng.token_buf.pop(r.rid, None)
            # recompute from scratch: greedy replay regenerates the same
            # tokens; the waste is tracked in recompute_tokens
            r.generated = 0
            r.prefill_layers_done = 0
            r.prefill_tokens_done = 0
            r.cached_tokens = 0    # re-claimed (if still cached) on re-admit
            self.offline_queue.append((r, toks, None))
            self.metrics.evictions += 1

    def _evict_probability(self) -> float:
        if not self.strict_pool:
            return 0.0
        return 0.5 * sum(s.pressure for s in self.strict_pool) / len(self.strict_pool)

    # ------------------------------------------------------------------
    # decode rounds
    # ------------------------------------------------------------------
    def _strict_round(self, slot: EngineSlot, now: float) -> float:
        self._push_cost = 0.0
        pf = None
        pre = 0.0
        if not self.relaxed_pool:
            # total relaxed-pool loss (crashes/promotions): strict engines
            # take over prefill so the cluster degrades instead of wedging
            if self.chunked:
                pf = self._pick_chunk_prefill(slot)
            else:
                pre = self._prefill_one(slot, now)
        cost, batch = self._decode_slot(slot, now + pre, relaxed=False,
                                        want_batch=True, prefill=pf)
        cost += pre
        if self.policy == "ooco" and batch:
            pull = self._pull_migration(slot, batch)
            # the pull's KV transfer rides the interconnect while the next
            # round's compute occupies the chips, so the round is charged
            # max(compute, transfer), not the sum (same overlap the
            # simulator models; deterministic — both terms are modeled)
            cost = max(cost, pull)
        return max(cost, self._push_cost)

    def _effective_slo(self, online, offline) -> float:
        """ooco mix-decoding SLO bound. Virtual mode: the perf model IS the
        clock, use the SLO directly. Wall mode: scale by the observed /
        predicted latency ratio (measured-latency calibration, PR 1)."""
        if self.clock.virtual:
            return self.slo_tpot
        sample = [r.context_len for r in (list(online) + list(offline)[:1])] or [8]
        pred = self.pm.decode_estimate(sample).latency or 1e-6
        scale = self.measured_tpot / pred
        return self.slo_tpot / max(scale, 1e-6)

    def _online_priority_cap(self) -> int:
        """Static decode-batch cap calibrated once at a conservative long
        context (HyGen/Echo-style heuristic baseline, paper §5.1.4)."""
        if self._op_cap is None:
            p95 = 1024
            lo, hi = 1, 4096
            while lo < hi:
                mid = (lo + hi + 1) // 2
                if self.pm.decode_estimate([p95] * mid).latency <= self.slo_tpot:
                    lo = mid
                else:
                    hi = mid - 1
            self._op_cap = lo
        return self._op_cap

    def _select_batch(self, slot: EngineSlot, relaxed: bool) -> list[Request]:
        online, offline = slot.online, slot.offline
        if relaxed:
            return online + offline[: self.relaxed_decode_cap]
        if self.policy == "base_pd":
            return online + offline
        if self.policy == "online_priority":
            cap = self._online_priority_cap()
            rest = sorted(offline, key=lambda r: r.context_len)
            return (online + rest)[: max(cap, len(online))]
        return sch.mix_decoding_selection(
            online, offline, self._effective_slo(online, offline), self.pm,
            rng=self.rng, mem_budget_bytes=self._pool_kv_bytes(slot))

    def _fit_batch(self, slot: EngineSlot, batch: list[Request]) -> list[Request]:
        """Page-budget admission for this decode step: online rows may evict
        offline residents to grow their tables; offline rows that do not fit
        just sit out the round (no OutOfPagesError on the hot path)."""
        cache = slot.engine.cache
        out: list[Request] = []
        need = 0
        for r in batch:
            if r.rid not in slot.engine.requests:
                continue   # evicted mid-fit by an earlier online row
            inc = cache.pages_for(r.context_len) - len(cache.tables.get(r.rid, []))
            free = cache.available_pages
            if need + inc <= free:
                out.append(r)
                need += inc
                continue
            if r.kind == Kind.ONLINE:
                shortfall = (need + inc - free) * cache.page_size
                self._evict_from(slot, shortfall,
                                 exclude={x.rid for x in out} | {r.rid})
                if need + inc <= cache.available_pages:
                    out.append(r)
                    need += inc
        if not out and batch:
            # full pool with nothing admissible: vLLM-style recompute
            # preemption — evict other offline residents to unblock the
            # head request, so a fully-offline engine never deadlocks
            r = batch[0]
            inc = cache.pages_for(r.context_len) - len(cache.tables.get(r.rid, []))
            self._evict_from(
                slot, (inc - cache.available_pages) * cache.page_size,
                exclude={r.rid})
            if r.rid in slot.engine.requests and inc <= cache.available_pages:
                out = [r]
        return out

    def _decode_slot(self, slot: EngineSlot, now: float, *, relaxed: bool,
                     want_batch: bool = False, prefill=None):
        """One engine round: decode batch + (chunked mode) a fused prefill
        chunk in the same dispatch. ``prefill`` is the ``(req, toks)`` entry
        chosen by ``_pick_chunk_prefill``."""
        slot.online = [r for r in slot.online if not r.done]
        slot.offline = [r for r in slot.offline if not r.done]
        empty = ((0.0, []) if want_batch else 0.0)
        pf_req = prefill[0] if prefill is not None else None
        if not slot.online and not slot.offline and pf_req is None:
            return empty
        if self.chunked:
            plan = self._plan_round(slot, relaxed, pf_req)
            batch = self._fit_batch(slot, plan.decode)
            chunk = plan.chunk_tokens if plan.prefill is not None else 0
            allowance = plan.horizon
            if chunk:
                # the decode batch's incremental pages are not allocated yet
                # (that happens inside the fused dispatch, AFTER the chunk's
                # scatter claims its pages) — reserve them here or the chunk
                # can starve the decode rows into OutOfPagesError. A
                # horizon allowance > 1 reserves claim-ahead to the horizon
                # END (one page claim per decode step per row), so neither
                # the chunk nor the decode side can starve the other
                # mid-scan
                cache = slot.engine.cache
                reserved = sum(
                    cache.pages_for(r.context_len - 1
                                    + min(allowance, max(r.remaining, 1)))
                    - len(cache.tables.get(r.rid, [])) for r in batch)
                chunk = self._fit_chunk(slot, pf_req, chunk,
                                        exclude={r.rid for r in batch},
                                        reserved_pages=reserved)
        else:
            batch = self._fit_batch(slot, self._select_batch(slot, relaxed))
            chunk = 0
            allowance = self._horizon_allowance(relaxed)
        if chunk:
            # chunked rounds fuse the horizon too (mixed-horizon dispatch):
            # K decode iterations ride the scan while the chunk lands as K
            # sub-chunk slices; a dropped chunk (page pressure) or online
            # work in the round falls back to K=1, keeping today's
            # preemption boundary exactly when latency is critical
            horizon = self._choose_mixed_horizon(slot, batch, pf_req, chunk,
                                                 allowance)
        else:
            horizon = self._choose_horizon(slot, batch, allowance)
        if not batch and not chunk:
            if (pf_req is not None and prefill in slot.prefilling
                    and not slot.offline):
                # full pool with nothing decodable and no chunk admissible:
                # vLLM-style recompute preemption — drop the landed prefix
                # so pinned prefills can never wedge the engine
                self._abort_chunk_prefill(slot, prefill)
            return empty
        dec_ctx = [r.context_len for r in batch]
        if chunk and horizon > 1:
            # one dispatch overhead for the whole fused mixed horizon;
            # chunk work summed per sub-chunk, decode at midpoint context
            est = self.pm.mixed_horizon_estimate(
                chunk, pf_req.prefill_tokens_done + chunk, dec_ctx, horizon,
                cached_tokens=pf_req.cached_tokens)
            # chunk-only share of the fused round — the denominator of
            # effective prefill throughput in the prefix-reuse bench
            self.metrics.prefill_modeled_seconds += \
                self.pm.mixed_horizon_estimate(
                    chunk, pf_req.prefill_tokens_done + chunk, (), horizon,
                    cached_tokens=pf_req.cached_tokens).latency
        elif chunk:
            est = self.pm.mixed_estimate(
                chunk, pf_req.prefill_tokens_done + chunk, dec_ctx,
                cached_tokens=pf_req.cached_tokens)
            # chunk-only share of the fused round — the denominator of
            # effective prefill throughput in the prefix-reuse bench
            self.metrics.prefill_modeled_seconds += self.pm.mixed_estimate(
                chunk, pf_req.prefill_tokens_done + chunk, (),
                cached_tokens=pf_req.cached_tokens).latency
        elif horizon > 1:
            # one dispatch overhead for the whole horizon — the virtual
            # clock charges the amortization the fused dispatch buys
            est = self.pm.horizon_estimate(dec_ctx, horizon)
        else:
            est = self.pm.decode_estimate(dec_ctx)
        if (self.injector is not None
                and self.injector.dispatch_stuck(slot.name, now)):
            # injected wedge: the dispatch would never return; the watchdog
            # kills it once the round exceeds watchdog_mult x the roofline-
            # predicted latency, and the round retries from intact state
            # (nothing was committed, so token parity is untouched)
            self.metrics.watchdog_aborts += 1
            cost = (est.latency * self.watchdog_mult
                    if self.clock.virtual else 0.0)
            return (cost, []) if want_batch else cost
        slot.last_bottleneck = est.bottleneck
        if not relaxed:
            online_lat = (self.pm.decode_estimate(
                [r.context_len for r in slot.online]).latency
                if slot.online else 0.0)
            slot.pressure = 0.9 * slot.pressure + 0.1 * min(
                online_lat / self.slo_tpot, 1.0)
        virtual = self.clock.virtual
        before = [r.decode_time_sum for r in batch] if virtual else None
        active = ([min(horizon, r.remaining) for r in batch]
                  if horizon > 1 else None)
        t0 = time.perf_counter()
        if chunk and horizon > 1:
            slot.engine.mixed_horizon([r.rid for r in batch], pf_req.rid,
                                      chunk, horizon)
            self.metrics.mixed_horizon_rounds += 1
        elif chunk:
            slot.engine.mixed_step([r.rid for r in batch], pf_req.rid, chunk)
        elif horizon > 1:
            slot.engine.decode_horizon([r.rid for r in batch], horizon)
            self.metrics.horizon_rounds += 1
        else:
            slot.engine.decode_step([r.rid for r in batch])
        dt = time.perf_counter() - t0
        step_lat = est.latency if virtual else dt
        if virtual:
            # the engine charged measured wall time; replace with modeled
            # time so TPOT metrics are bit-deterministic across replays
            # (a horizon row is charged its amortized share of the fused
            # dispatch — early-exit rows only for the steps they ran)
            for i, (r, b) in enumerate(zip(batch, before)):
                share = (active[i] / horizon) if active is not None else 1.0
                r.decode_time_sum = b + est.latency * share
        if not relaxed:
            self.measured_tpot = 0.8 * self.measured_tpot + 0.2 * step_lat
        for r in batch:
            if r.done:
                self._finish(r, slot.engine, now + step_lat)
        cost = step_lat
        if chunk:
            cost += self._after_chunk(slot, pf_req, now, step_lat)
        return (cost, batch) if want_batch else cost

    def _abort_chunk_prefill(self, slot: EngineSlot, entry) -> None:
        """Discard a pinned chunk prefill's landed prefix and requeue the
        request (recompute later, counted in ``recompute_tokens``)."""
        req, toks = entry
        slot.prefilling.remove(entry)
        eng = slot.engine
        eng.abort_prefill(req.rid)
        eng.requests.pop(req.rid, None)
        eng.token_buf.pop(req.rid, None)
        if req.kind == Kind.ONLINE:
            self.online_queue.insert(0, (req, toks))
        else:
            self.offline_queue.append((req, toks, None))

    def _fit_chunk(self, slot: EngineSlot, req: Request, chunk: int,
                   exclude: set[int], reserved_pages: int = 0) -> int:
        """Page-budget admission for the round's prefill chunk: shrink it to
        the KV capacity left after the decode batch's reservations
        (``reserved_pages``, claimed inside the dispatch after the chunk's
        scatter; online prefills may evict offline residents first). A zero
        here just defers the chunk — the landed prefix stays pinned and
        resumes later."""
        cache = slot.engine.cache
        done = req.prefill_tokens_done
        slack = len(cache.tables.get(req.rid, [])) * cache.page_size - done

        def free_tok() -> int:
            free = cache.available_pages - reserved_pages
            return max(free, 0) * cache.page_size + max(slack, 0)

        avail = free_tok()
        if req.kind == Kind.ONLINE and chunk > avail:
            self._evict_from(slot, chunk - avail, exclude=exclude | {req.rid})
            avail = free_tok()
        return min(chunk, avail)

    def _pull_migration(self, slot: EngineSlot, batch: list[Request]) -> float:
        """§3.4.3 pull-model migration: a strict engine with SLO headroom
        computes its bottleneck-filling length preference (Alg. 1) and
        absorbs matching offline decodes from the relaxed pool. Returns the
        modeled transfer cost (charged to the strict round — pulls are not
        free under the virtual clock)."""
        all_included = len(batch) == slot.resident
        pref = sch.migration_decision(
            batch, all_included,
            self.slo_tpot if self.clock.virtual
            else self._effective_slo(slot.online, slot.offline),
            self.pm, mem_budget_bytes=self._free_kv_bytes(slot))
        if pref is None:
            return 0.0
        src_of = {r.rid: rs for rs in self.relaxed_pool
                  for r in rs.offline if not r.done}
        chosen = sch.select_for_migration(
            [r for rs in self.relaxed_pool for r in rs.offline if not r.done],
            pref)
        cost = 0.0
        for r in chosen:
            src = src_of[r.rid]
            if not slot.engine.cache.can_fit(src.engine.cache.lengths[r.rid]):
                break
            src.offline.remove(r)
            cost += self._migrate(r, src, slot)
            self.metrics.pulls += 1
        return cost

    # ------------------------------------------------------------------
    def _finish(self, req: Request, eng: ServingEngine, t: float) -> None:
        req.phase = Phase.FINISHED
        req.finish_time = t
        self.tokens[req.rid] = eng.token_buf[req.rid].tolist()
        self.finished.append(req)
        if self.draining:
            self.metrics.drained += 1

    # ------------------------------------------------------------------
    # trace-driven event loop
    # ------------------------------------------------------------------
    def run(self, online: list[TraceRequest], offline: list[TraceRequest], *,
            duration: float | None = None, max_prompt: int = 64,
            max_output: int = 32, drain: bool = True,
            max_rounds: int = 200_000) -> dict:
        """Admit trace arrivals, step the pools until the work drains (or
        ``duration`` in no-drain mode), return the metrics summary.

        Prompt tokens are synthesized deterministically from ``seed`` and
        quantized to multiples of 8 (bounds jit-compilation variants);
        arrivals after ``duration`` are dropped. Idle rounds skip to the
        next arrival — virtually (clock jump) or by sleeping (wall)."""
        rng = np.random.default_rng(self.seed)
        self.clock.reset()   # construction/compile time is not trace time
        pending = sorted(
            [(t.arrival, 0, i, t) for i, t in enumerate(online)]
            + [(t.arrival, 1, i, t) for i, t in enumerate(offline)])
        if duration is not None:
            pending = [p for p in pending if p[0] <= duration]
        self._next_online_arrival = lambda: next(
            (p[0] for p in pending if p[1] == 0), None)
        # scan past any due offline arrivals: an online request queued
        # behind them must still trigger the §3.4.1 wall-mode probe
        self.incoming_online = lambda: any(
            p[0] <= self.clock.now() for p in pending if p[1] == 0)
        hard_cap = 10 * duration if duration else float("inf")

        def make_tokens(t: TraceRequest) -> list[int]:
            if getattr(t, "tokens", None) is not None:
                # trace carries explicit content (shared-prefix workloads);
                # trim to the runtime cap but keep the prefix intact so
                # cross-request reuse survives the clip
                return [int(x) for x in t.tokens[:max_prompt]]
            n = int(np.clip(-(-t.prompt_len // 8) * 8, 8, max_prompt))
            return [int(x) for x in rng.integers(0, self.cfg.vocab_size, n)]

        while True:
            now = self.clock.now()
            while pending and pending[0][0] <= now:
                arr, kcode, _, t = pending.pop(0)
                kind = Kind.ONLINE if kcode == 0 else Kind.OFFLINE
                toks = make_tokens(t)
                req = Request(kind, arr, len(toks),
                              max(min(t.output_len, max_output), 1))
                self.submit(req, toks)
            if duration is not None and now >= duration and not drain:
                break
            if now > hard_cap or self.metrics.rounds >= max_rounds:
                break
            worked = self.step()
            if not worked:
                if pending:
                    self.metrics.idle_rounds += 1
                    self.clock.idle_until(pending[0][0])
                    continue
                break
        return self.summary()

    # ------------------------------------------------------------------
    def summary(self) -> dict:
        """TTFT/TPOT percentiles, SLO attainment, offline goodput, and the
        preemption/migration/eviction counters — the policy-comparison
        record (deterministic under the virtual clock: no wall times)."""
        elapsed = max(self.clock.now(), 1e-9)
        # SLO accounting under live lifecycles: a CLIENT-cancelled request
        # leaves the attainment denominator (the server cannot violate an
        # SLO the client walked away from), but a DEADLINE abort is always
        # billed as a violation — a deadline miss must never launder itself
        # into attainment by being aborted.
        online = [r for r in self.all_requests if r.kind == Kind.ONLINE
                  and not (r.phase is Phase.CANCELLED
                           and r.cancel_reason != "deadline")]
        offline = [r for r in self.all_requests if r.kind == Kind.OFFLINE]
        ttfts = [r.ttft() for r in online if r.ttft() is not None]
        tpots = [r.avg_tpot() for r in online if r.avg_tpot() is not None]
        viol = sum(1 for r in online
                   if (r.phase is Phase.CANCELLED
                       and r.cancel_reason == "deadline")
                   or r.violates(self.slo_ttft, self.slo_tpot, now=elapsed))
        off_tokens = int(sum(r.generated for r in offline))
        # §3.4.1 preemptions: layer-level interruptions (legacy path) plus
        # chunk-boundary pauses of in-progress offline prefills
        preempt = (sum(s.engine.stats.preemptions
                       for s in self.relaxed_pool + self.dead_pool)
                   + self.metrics.chunk_preemptions)
        pools = self.strict_pool + self.relaxed_pool + self.dead_pool
        return {
            "policy": self.policy,
            "n_strict": len(self.strict_pool),
            "n_relaxed": len(self.relaxed_pool),
            "clock": "virtual" if self.clock.virtual else "wall",
            "elapsed": float(elapsed),
            "online_requests": len(online),
            "online_finished": sum(1 for r in online if r.done),
            "online_slo_attainment": 1.0 - viol / max(len(online), 1),
            "online_ttft_p50": _pct(ttfts, 50),
            "online_ttft_p99": _pct(ttfts, 99),
            "online_tpot_p50": _pct(tpots, 50),
            "online_tpot_p99": _pct(tpots, 99),
            "offline_requests": len(offline),
            "offline_finished": sum(1 for r in offline if r.done),
            "offline_tokens": off_tokens,
            "offline_tokens_per_s": off_tokens / elapsed,
            "recompute_tokens": int(sum(r.recompute_tokens
                                        for r in self.all_requests)),
            "preemptions": int(preempt),
            "chunks": self.metrics.chunks,
            "chunk_preemptions": self.metrics.chunk_preemptions,
            # host_syncs = device->host syncs on the token path (one per
            # engine dispatch that returns tokens); horizon_steps = decode
            # iterations executed inside K>1 fused horizons — together they
            # record how much host round-tripping the horizons removed
            "host_syncs": int(sum(s.engine.stats.host_syncs for s in pools)),
            "horizon_steps": int(sum(s.engine.stats.horizon_steps
                                     for s in pools)),
            "horizon_rounds": self.metrics.horizon_rounds,
            "mixed_horizon_rounds": self.metrics.mixed_horizon_rounds,
            # dispatch counts per kind across all engines — amortization is
            # observable directly (a mixed_horizon dispatch covers K decode
            # steps AND K prefill sub-chunks), not just via host_syncs
            "dispatches_by_kind": {
                kind: int(sum(s.engine.stats.dispatches_by_kind[kind]
                              for s in pools))
                for kind in ("prefill", "decode", "mixed", "horizon",
                             "mixed_horizon")},
            "migrations": self.metrics.migrations,
            "pulls": self.metrics.pulls,
            "evictions": self.metrics.evictions,
            # cross-request KV reuse: prompt claims against the radix
            # prefix cache (hits / tokens served / pages shared at claim
            # time) and tree pages dropped under pool pressure
            "prefix_cache": self.prefix_cache,
            "prefix_hits": int(sum(s.engine.stats.prefix_hits
                                   for s in pools)),
            "cached_tokens": int(sum(s.engine.stats.cached_tokens
                                     for s in pools)),
            "shared_pages": int(sum(s.engine.stats.shared_pages
                                    for s in pools)),
            "prefix_evictions": int(sum(
                s.engine.cache.prefix.evictions for s in pools
                if s.engine.cache.prefix is not None)),
            "prefill_tokens": int(sum(s.engine.stats.prefill_tokens
                                      for s in pools)),
            "prefill_modeled_seconds": float(
                self.metrics.prefill_modeled_seconds),
            "rounds": self.metrics.rounds,
            "idle_rounds": self.metrics.idle_rounds,
            # fault-tolerance counters: nonzero only under injected chaos
            # or genuine overload; shed work is surfaced here, never silent
            "faults_injected": (self.injector.faults_injected
                                if self.injector else 0),
            "engine_crashes": self.metrics.engine_crashes,
            "promotions": self.metrics.promotions,
            "recoveries": self.metrics.recoveries,
            "migration_retries": self.metrics.migration_retries,
            "migration_recomputes": self.metrics.migration_recomputes,
            "watchdog_aborts": self.metrics.watchdog_aborts,
            "shed_requests": self.metrics.shed_requests,
            "degraded_rounds": self.metrics.degraded_rounds,
            # live lifecycle (gateway): every submitted request ends in
            # exactly one terminal state — finished, cancelled (client),
            # deadline-aborted, rejected at admission, or shed
            "cancelled": self.metrics.cancelled,
            "deadline_aborts": self.metrics.deadline_aborts,
            "rejected_online": self.metrics.rejected_online,
            "drained": self.metrics.drained,
        }

    def finished_signature(self) -> list[tuple]:
        """Trace-stable identity of every finished request + its full token
        stream (rids are process-global, so determinism tests compare this)."""
        return sorted(
            (r.kind.value, round(r.arrival, 9), r.prompt_len, r.output_len,
             tuple(self.tokens.get(r.rid, ())))
            for r in self.finished)
