"""Integration: the REAL co-located server (two engines, OOCO data path)."""
import time

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.request import Kind, Request
from repro.launch.serve import CoLocatedServer


@pytest.fixture(scope="module")
def server():
    cfg = get_config("qwen2.5-7b").reduced()
    return CoLocatedServer(cfg, policy="ooco", num_pages=256, page_size=8)


def test_online_offline_coexist(server):
    cfg = server.cfg
    rng = np.random.default_rng(0)

    def toks(n):
        return list(rng.integers(0, cfg.vocab_size, n))

    offline = [Request(Kind.OFFLINE, 0.0, 24, 6) for _ in range(3)]
    online = [Request(Kind.ONLINE, 0.0, 12, 4) for _ in range(2)]
    for r in offline:
        server.submit(r, toks(r.prompt_len))
    server.step()  # offline prefill starts
    for r in online:
        server.submit(r, toks(r.prompt_len))
    for _ in range(60):
        server.step()
        if all(r.done for r in online + offline):
            break
    assert all(r.done for r in online), "online requests must finish"
    assert all(r.done for r in offline), "offline requests must finish"
    # online got first tokens (TTFT recorded)
    assert all(r.first_token_time is not None for r in online)
    # the strict engine decoded; under ooco the relaxed engine decodes too
    assert server.strict.stats.decode_steps > 0


def test_layer_preemption_fires_under_contention(server):
    """An offline prefill in flight when online work "arrives" (the
    incoming_online probe flips mid-prefill) must be interrupted at a layer
    boundary, then resume and still finish correctly (§3.4.1)."""
    cfg = server.cfg
    rng = np.random.default_rng(1)
    before = server.relaxed.stats.preemptions
    off = Request(Kind.OFFLINE, 0.0, 40, 4)
    on = Request(Kind.ONLINE, 0.0, 8, 3)
    server.submit(off, list(rng.integers(0, cfg.vocab_size, 40)))
    calls = [0]

    def arrival_probe():  # online request lands after the first layer
        # (the 2-layer reduced model polls once, between layers 0 and 1)
        calls[0] += 1
        return calls[0] >= 1

    server.incoming_online = arrival_probe
    server.step()   # offline prefill starts and gets interrupted
    assert server.relaxed.stats.preemptions > before
    assert off.prefill_layers_done > 0 and not off.done
    server.incoming_online = lambda: False
    server.submit(on, list(rng.integers(0, cfg.vocab_size, 8)))
    for _ in range(80):
        server.step()
        if off.done and on.done:
            break
    assert on.done and off.done
