"""Property-based tests for OOCO's scheduling points (Algorithms 1 & 2)."""
import random

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core import scheduling as sch
from repro.core.hardware import TPU_V5E
from repro.core.perf_model import PerfModel
from repro.core.request import Kind, Request

PM = PerfModel(get_config("qwen2.5-7b"), TPU_V5E, tp=4)
SLO = 0.1
BUDGET = TPU_V5E.hbm_capacity * 0.9 - PM.weight_bytes()


def _reqs(kind, lens):
    out = []
    for l in lens:
        r = Request(kind, 0.0, int(max(l, 1)), 10)
        out.append(r)
    return out


lens_st = st.lists(st.integers(1, 8000), min_size=0, max_size=40)


class TestMixDecoding:
    @given(on=lens_st, off=lens_st, seed=st.integers(0, 5))
    @settings(max_examples=60, deadline=None)
    def test_invariants(self, on, off, seed):
        online = _reqs(Kind.ONLINE, on)
        offline = _reqs(Kind.OFFLINE, off)
        batch = sch.mix_decoding_selection(online, offline, SLO, PM,
                                           rng=random.Random(seed),
                                           mem_budget_bytes=BUDGET)
        # 1) every online request is always included, in order
        assert batch[: len(online)] == online
        # 2) no duplicates, all from the candidate set
        ids = [r.rid for r in batch]
        assert len(set(ids)) == len(ids)
        assert set(ids) <= {r.rid for r in online + offline}
        # 3) if any offline was admitted, predicted latency respects the SLO
        if len(batch) > len(online):
            lat = PM.decode_estimate([r.context_len for r in batch]).latency
            assert lat <= SLO * (1 + 1e-9)
            assert PM.kv_bytes([r.context_len for r in batch]) <= BUDGET * (1 + 1e-9)

    @given(off=st.lists(st.integers(1, 4000), min_size=1, max_size=30))
    @settings(max_examples=30, deadline=None)
    def test_no_online_fills_with_offline(self, off):
        offline = _reqs(Kind.OFFLINE, off)
        batch = sch.mix_decoding_selection([], offline, SLO, PM,
                                           mem_budget_bytes=BUDGET)
        assert len(batch) >= 1  # SLO is generous enough for at least one

    def test_online_over_slo_excludes_offline(self):
        # enough very long online requests to exceed the SLO by themselves
        online = _reqs(Kind.ONLINE, [32768] * 512)
        offline = _reqs(Kind.OFFLINE, [100] * 10)
        batch = sch.mix_decoding_selection(online, offline, SLO, PM,
                                           mem_budget_bytes=None)
        assert batch == online  # best-effort mode: online only


class TestMigration:
    def _batch(self, n, l=1000):
        return _reqs(Kind.ONLINE, [l] * n)

    def test_no_headroom_no_migration(self):
        batch = self._batch(600, 4000)  # way over SLO
        pref = sch.migration_decision(batch, True, SLO, PM,
                                      mem_budget_bytes=BUDGET)
        assert pref is None

    def test_not_all_included_no_migration(self):
        pref = sch.migration_decision(self._batch(4), False, SLO, PM,
                                      mem_budget_bytes=BUDGET)
        assert pref is None

    def test_small_batch_prefers_reaching_saturation(self):
        batch = self._batch(4, 200)
        pref = sch.migration_decision(batch, True, SLO, PM,
                                      mem_budget_bytes=BUDGET)
        assert pref is not None
        assert pref.mode in ("bounded", "shortest")
        if pref.mode == "bounded":
            assert pref.count >= 1

    def test_saturated_batch_prefers_longest(self):
        bs_sat = PM.compute_saturated_batch(500)
        batch = self._batch(bs_sat + 10, 500)
        pref = sch.migration_decision(batch, True, 10.0, PM,  # generous SLO
                                      mem_budget_bytes=BUDGET * 100)
        assert pref is not None and pref.mode == "longest"
        assert pref.target_len >= 1

    @given(n=st.integers(1, 50), l=st.integers(100, 4000))
    @settings(max_examples=20, deadline=None)
    def test_preference_respects_slo(self, n, l):
        batch = self._batch(n, l)
        pref = sch.migration_decision(batch, True, SLO, PM,
                                      mem_budget_bytes=BUDGET)
        if pref is None or pref.mode == "shortest":
            return
        ctx = [r.context_len for r in batch] + [pref.target_len] * (
            pref.count if pref.mode == "bounded" else 1)
        assert PM.decode_estimate(ctx).latency <= SLO * (1 + 1e-6)


class TestEviction:
    def test_compute_bound_evicts_longest(self):
        reqs = _reqs(Kind.OFFLINE, [100, 5000, 300, 2000])
        v = sch.select_eviction_victims(reqs, 4000, "compute")
        assert v[0].context_len == 5000  # few long victims

    def test_memory_bound_evicts_shortest(self):
        reqs = _reqs(Kind.OFFLINE, [100, 5000, 300, 2000])
        v = sch.select_eviction_victims(reqs, 350, "memory")
        assert [r.context_len for r in v] == [100, 300]

    @given(lens=st.lists(st.integers(1, 5000), min_size=1, max_size=20),
           need=st.integers(1, 20000),
           bn=st.sampled_from(["compute", "memory", "balanced"]))
    @settings(max_examples=40, deadline=None)
    def test_frees_enough_or_everything(self, lens, need, bn):
        reqs = _reqs(Kind.OFFLINE, lens)
        v = sch.select_eviction_victims(reqs, need, bn)
        freed = sum(r.context_len for r in v)
        assert freed >= min(need, sum(lens)) or len(v) == len(reqs)


class TestGating:
    def test_idle_node_always_prefills(self):
        cand = Request(Kind.OFFLINE, 0.0, 1000, 100)
        assert sch.gating_decision(cand, [], PM, evict_probability=1.0,
                                   horizon_seconds=10.0,
                                   mem_budget_bytes=BUDGET)

    def test_memory_full_rejects(self):
        cand = Request(Kind.OFFLINE, 0.0, 1000, 100)
        cur = _reqs(Kind.OFFLINE, [1000] * 8)
        assert not sch.gating_decision(cand, cur, PM, evict_probability=0.0,
                                       horizon_seconds=10.0,
                                       mem_budget_bytes=1.0)

    def test_monotone_in_eviction_risk(self):
        """Higher eviction probability can only flip accept -> reject."""
        cand = Request(Kind.OFFLINE, 0.0, 2000, 100)
        cur = _reqs(Kind.OFFLINE, [1500] * 16)
        results = [sch.gating_decision(cand, cur, PM, evict_probability=p,
                                       horizon_seconds=5.0,
                                       mem_budget_bytes=BUDGET)
                   for p in (0.0, 0.25, 0.5, 0.75, 1.0)]
        # once it flips to False it stays False
        flipped = False
        for r in results:
            if flipped:
                assert not r
            flipped = flipped or (not r)


class TestTokenBudgetSchedule:
    def _pf(self, prompt=2048, done=0, kind=Kind.OFFLINE):
        r = Request(kind, 0.0, prompt, 10)
        r.prefill_tokens_done = done
        return r

    def test_no_prefill_is_pure_decode(self):
        online = _reqs(Kind.ONLINE, [100] * 4)
        plan = sch.token_budget_schedule(online, [], None, 0, PM, slo=SLO)
        assert plan.prefill is None and plan.chunk_tokens == 0
        assert plan.decode[: len(online)] == online

    def test_slo_bounds_fused_step(self):
        """Any scheduled chunk keeps the predicted fused-step latency within
        the SLO; online decodes always ride."""
        online = _reqs(Kind.ONLINE, [2000] * 6)
        pf = self._pf()
        plan = sch.token_budget_schedule(online, [], pf, pf.prompt_len, PM,
                                         slo=SLO)
        assert plan.decode[: len(online)] == online
        if plan.chunk_tokens:
            est = PM.mixed_estimate(
                plan.chunk_tokens, plan.chunk_tokens,
                [r.context_len for r in plan.decode])
            assert est.latency <= SLO * (1 + 1e-9)

    def test_tight_slo_defers_chunk_never_decode(self):
        online = _reqs(Kind.ONLINE, [4000] * 8)
        pf = self._pf()
        plan = sch.token_budget_schedule(online, [], pf, pf.prompt_len, PM,
                                         slo=1e-7)
        assert plan.decode[: len(online)] == online
        assert plan.prefill is None and plan.chunk_tokens == 0

    def test_relaxed_round_floors_chunk_at_bucket(self):
        """A resident decode batch can never starve prefill progress on a
        latency-relaxed round."""
        offline = _reqs(Kind.OFFLINE, [3000] * 30)
        pf = self._pf()
        plan = sch.token_budget_schedule([], offline, pf, pf.prompt_len, PM,
                                         slo=None, relaxed_cap=16,
                                         budget_tokens=8)
        assert len(plan.decode) == 16
        assert plan.chunk_tokens >= 8

    def test_online_prefill_runs_whole_on_relaxed(self):
        """Chunking exists to pause OFFLINE prefill; an online prompt on a
        relaxed round lands whole (chunking it only defers its own TTFT)."""
        pf = self._pf(prompt=1536, done=512, kind=Kind.ONLINE)
        plan = sch.token_budget_schedule([], [], pf, 1024, PM, slo=None,
                                         budget_tokens=128)
        assert plan.chunk_tokens == 1024
        off = self._pf(prompt=1536, done=512)
        plan = sch.token_budget_schedule([], [], off, 1024, PM, slo=None,
                                         budget_tokens=128)
        assert plan.chunk_tokens == 128

    def test_chunk_never_exceeds_remaining(self):
        pf = self._pf(prompt=100, done=90)
        plan = sch.token_budget_schedule([], [], pf, 10, PM, slo=None,
                                         budget_tokens=4096)
        assert plan.chunk_tokens == 10
        assert plan.total_tokens == 10
