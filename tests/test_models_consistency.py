"""Prefill+decode must equal one longer prefill (cache/rope/ring-buffer
correctness across every family)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models.model import build_model

B, S, EXTRA = 2, 32, 6


@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_matches_prefill(arch, rng):
    cfg = get_config(arch).reduced()
    model = build_model(cfg, remat=False)
    params = model.init(rng)
    total = S + EXTRA
    tokens = jax.random.randint(rng, (B, total), 0, cfg.vocab_size)
    bf, bp = {"tokens": tokens}, {"tokens": tokens[:, :S]}
    extra_ctx = 0
    if cfg.frontend == "vision":
        fe = jax.random.normal(rng, (B, cfg.num_frontend_tokens, cfg.d_model),
                               jnp.bfloat16)
        bf["frontend_embeds"] = bp["frontend_embeds"] = fe
        extra_ctx = cfg.num_frontend_tokens
    if cfg.family == "audio":
        fe = jax.random.normal(rng, (B, 64, cfg.d_model), jnp.bfloat16)
        bf["frontend_embeds"] = bp["frontend_embeds"] = fe
    C = total + extra_ctx
    ref, _ = model.prefill(params, bf, cache_len=C)
    logits, cache = model.prefill(params, bp, cache_len=C)
    for t in range(S, total):
        logits, cache = model.decode_step(params, tokens[:, t], cache)
    ref = np.asarray(ref, np.float32)
    got = np.asarray(logits, np.float32)
    rel = np.max(np.abs(ref - got)) / (np.max(np.abs(ref)) + 1e-9)
    assert rel < 0.05, f"{arch}: rel err {rel}"


def test_windowed_ring_buffer_cache(rng):
    """Decode with a ring-buffer cache smaller than the sequence matches a
    sliding-window prefill (mixtral-style SWA)."""
    cfg = get_config("mixtral-8x22b").reduced()
    # reduced() clamps sliding_window to 64 > our seq; shrink further
    import dataclasses
    cfg = dataclasses.replace(cfg, sliding_window=16)
    model = build_model(cfg, remat=False)
    params = model.init(rng)
    total = 48
    tokens = jax.random.randint(rng, (B, total), 0, cfg.vocab_size)
    ref, _ = model.prefill(params, {"tokens": tokens}, cache_len=16)
    logits, cache = model.prefill(params, {"tokens": tokens[:, :40]}, cache_len=16)
    for t in range(40, total):
        logits, cache = model.decode_step(params, tokens[:, t], cache)
    rel = (np.max(np.abs(np.asarray(ref) - np.asarray(logits)))
           / (np.max(np.abs(np.asarray(ref))) + 1e-9))
    assert rel < 0.05, rel
