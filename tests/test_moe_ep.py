"""Expert-parallel shard_map MoE vs the single-device oracle.

Runs on a (1, 2)-device mesh in a subprocess (the only other place besides
the dry-run that forces a host device count)."""
import json
import subprocess
import sys

CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import dataclasses, json
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import moe as moe_lib
from repro.models.moe_ep import moe_mlp_ep, moe_ep_ref, pad_experts

cfg = dataclasses.replace(get_config("granite-moe-3b-a800m").reduced(),
                          moe_capacity_factor=8.0)  # no drops
_axis_type = getattr(jax.sharding, "AxisType", None)  # newer jax only
mesh = (jax.make_mesh((1, 2), ("data", "model"),
                      axis_types=(_axis_type.Auto,) * 2)
        if _axis_type is not None else jax.make_mesh((1, 2), ("data", "model")))
p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
pp, E_pad = pad_experts(p, cfg, mesh.shape["model"])
assert E_pad % 2 == 0
x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model), jnp.float32)
with jax.sharding.use_mesh(mesh) if hasattr(jax.sharding, "use_mesh") else mesh:
    out = moe_mlp_ep(pp, x, cfg, mesh)
ref = moe_ep_ref(pp, x, cfg)
err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))))
print(json.dumps({"err": err}))
assert err < 5e-3, err
"""


def test_moe_ep_matches_oracle():
    out = subprocess.run(
        [sys.executable, "-c", CODE], capture_output=True, text=True,
        timeout=600, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                          "HOME": "/root",
                          # forces *host* devices; skip the TPU-backend probe
                          "JAX_PLATFORMS": "cpu"})
    assert out.returncode == 0, out.stderr[-3000:]
    err = json.loads(out.stdout.strip().splitlines()[-1])["err"]
    assert err < 5e-3
