"""Sharding rules: every parameter of every assigned arch gets a
rank-correct, divisibility-correct PartitionSpec for the 16x16 mesh —
catching bad rules without compiling."""
import jax
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.launch import specs as sp
from repro.models.config import INPUT_SHAPES
from repro.models.model import build_model
from repro.sharding import rules

MESH = {"data": 16, "model": 16}


@pytest.mark.parametrize("arch", ASSIGNED)
def test_param_specs_valid(arch):
    cfg = get_config(arch)
    model = build_model(cfg)
    params_abs = sp.abstract_params(model)
    pspecs = rules.param_specs(params_abs, MESH)
    leaves = jax.tree_util.tree_leaves_with_path(params_abs)
    spec_leaves = jax.tree_util.tree_leaves(
        pspecs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    assert len(leaves) == len(spec_leaves)
    for (path, leaf), spec in zip(leaves, spec_leaves):
        assert len(spec) <= leaf.ndim, (path, spec, leaf.shape)
        for dim, ax in zip(leaf.shape, spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = int(np.prod([MESH[a] for a in axes]))
            assert dim % size == 0, (path, spec, leaf.shape)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_big_params_are_sharded(arch):
    """No tensor above 64 MB may stay fully replicated (HBM discipline)."""
    cfg = get_config(arch)
    model = build_model(cfg)
    params_abs = sp.abstract_params(model)
    pspecs = rules.param_specs(params_abs, MESH)
    leaves = jax.tree_util.tree_leaves_with_path(params_abs)
    spec_leaves = jax.tree_util.tree_leaves(
        pspecs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    for (path, leaf), spec in zip(leaves, spec_leaves):
        nbytes = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        key = "/".join(str(getattr(q, "key", q)) for q in path)
        if key.endswith("embed") and leaf.shape[0] % MESH["model"]:
            continue  # replicated by design (XLA gather-partitioner bug)
        if nbytes > 64e6:
            assert any(ax is not None for ax in spec), (path, leaf.shape)


@pytest.mark.parametrize("arch", ASSIGNED)
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_cache_specs_valid(arch, shape_name):
    from repro.launch.dryrun import skip_reason
    if skip_reason(arch, shape_name):
        pytest.skip("assigned skip")
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    model = build_model(cfg, long_context=(shape_name == "long_500k"))
    cache = sp.abstract_cache(model, shape)
    cspecs = rules.cache_specs(cfg, cache, shape.global_batch, False, MESH)
    for key, leaf in cache.items():
        spec = cspecs[key]
        assert len(spec) <= leaf.ndim, (key, spec, leaf.shape)
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = int(np.prod([MESH[a] for a in axes]))
            assert dim % size == 0, (key, spec, leaf.shape)


def test_batch_axis_divisibility():
    assert rules.batch_axis(256, False, MESH) == ("data",)
    assert rules.batch_axis(1, False, MESH) is None
    assert rules.batch_axis(8, False, MESH) is None  # 8 % 16 != 0
    m3 = {"pod": 2, "data": 16, "model": 16}
    assert rules.batch_axis(256, True, m3) == ("pod", "data")
    assert rules.batch_axis(32, True, m3) == ("pod", "data")
    assert rules.batch_axis(2, True, m3) == ("pod",)
