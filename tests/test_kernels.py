"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_prefill.ops import flash_attention
from repro.kernels.paged_attention.ops import paged_attention

FLASH_CASES = [
    # B, Sq, Skv, Hq, Hkv, hd, causal, window, softcap
    (2, 128, 128, 4, 2, 64, True, 0, 0.0),
    (1, 256, 256, 8, 8, 128, True, 0, 50.0),
    (2, 128, 128, 4, 1, 64, True, 64, 0.0),
    (1, 100, 100, 2, 2, 32, True, 0, 0.0),       # non-multiple-of-block
    (2, 64, 192, 4, 4, 64, False, 0, 0.0),       # encoder (non-causal)
    (1, 64, 64, 2, 2, 48, True, 16, 30.0),       # window + softcap
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_prefill_vs_ref(case, dtype, rng):
    B, Sq, Skv, Hq, Hkv, hd, causal, window, cap = case
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, hd), dtype)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          logit_softcap=cap, interpret=True,
                          block_q=64, block_kv=64)
    ref = flash_attention(q, k, v, causal=causal, window=window,
                          logit_softcap=cap, use_ref=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


PAGED_CASES = [
    # B, Hq, Hkv, hd, page, P, npages, softcap
    (2, 4, 2, 64, 16, 4, 32, 0.0),
    (3, 8, 8, 128, 8, 6, 64, 50.0),
    (1, 4, 1, 32, 32, 2, 8, 0.0),
    (4, 2, 2, 64, 4, 8, 64, 0.0),
]


@pytest.mark.parametrize("case", PAGED_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_vs_ref(case, dtype, rng):
    B, Hq, Hkv, hd, page, P, npages, cap = case
    ks = jax.random.split(rng, 4)
    q = jax.random.normal(ks[0], (B, Hq, hd), dtype)
    kp = jax.random.normal(ks[1], (npages, page, Hkv, hd), dtype)
    vp = jax.random.normal(ks[2], (npages, page, Hkv, hd), dtype)
    tables = jax.random.randint(ks[3], (B, P), 0, npages)
    lengths = jax.random.randint(ks[3], (B,), 1, P * page + 1)
    out = paged_attention(q, kp, vp, tables, lengths, num_kv_heads=Hkv,
                          logit_softcap=cap, interpret=True)
    ref = paged_attention(q, kp, vp, tables, lengths, num_kv_heads=Hkv,
                          logit_softcap=cap, use_ref=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_paged_attention_ignores_garbage_past_length(rng):
    """Pages past `length` must not affect the output (masking contract)."""
    B, Hkv, hd, page, P, npages = 1, 2, 32, 8, 4, 16
    ks = jax.random.split(rng, 4)
    q = jax.random.normal(ks[0], (B, 2, hd))
    kp = jax.random.normal(ks[1], (npages, page, Hkv, hd))
    vp = jax.random.normal(ks[2], (npages, page, Hkv, hd))
    tables = jnp.array([[3, 7, 1, 2]], jnp.int32)
    lengths = jnp.array([11], jnp.int32)  # only pages 0-1 partially used
    out1 = paged_attention(q, kp, vp, tables, lengths, num_kv_heads=Hkv, use_ref=True)
    tables2 = jnp.array([[3, 7, 9, 14]], jnp.int32)  # garbage tail pages
    out2 = paged_attention(q, kp, vp, tables2, lengths, num_kv_heads=Hkv, use_ref=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)
