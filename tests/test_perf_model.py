"""Roofline perf model tests (paper §3.3: Tables 2–4, Eq. 1)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import ASSIGNED, get_config
from repro.core.hardware import TPU_V5E
from repro.core.perf_model import PerfModel

ARCHS = ["qwen2.5-7b", "mixtral-8x22b", "rwkv6-1.6b", "zamba2-7b",
         "whisper-tiny", "gemma2-2b", "granite-moe-3b-a800m"]


@pytest.mark.parametrize("arch", ARCHS)
def test_fast_path_matches_detailed(arch):
    pm = PerfModel(get_config(arch), TPU_V5E, tp=2)
    ctx = list(np.random.default_rng(0).integers(1, 8000, 64))
    fast = pm.decode_estimate(ctx)
    slow = pm.decode_estimate(ctx, detail=True)
    assert fast.latency == pytest.approx(slow.latency, rel=1e-9)
    assert fast.flops == pytest.approx(slow.flops, rel=1e-9)
    assert fast.bytes == pytest.approx(slow.bytes, rel=1e-9)


@pytest.mark.parametrize("arch", ARCHS)
def test_latency_curve_matches_full_estimate(arch):
    pm = PerfModel(get_config(arch), TPU_V5E)
    rng = np.random.default_rng(1)
    base = rng.integers(1, 4000, 16).astype(float)
    extras = np.sort(rng.integers(1, 4000, 24)).astype(float)
    curve = pm.decode_latency_curve(base, extras)
    assert curve.shape == (25,)
    for k in (0, 7, 24):
        full = pm.decode_estimate(list(base) + list(extras[:k])).latency
        assert curve[k] == pytest.approx(full, rel=1e-9)
    assert np.all(np.diff(curve) >= -1e-12)  # monotone in k


def test_eq1_roofline_max():
    """Eq. 1: op latency = max(flops/F, bytes/M) — both regimes exercised."""
    pm = PerfModel(get_config("qwen2.5-7b"), TPU_V5E)
    # decode B=1 is memory-bound; big prefill is compute-bound
    d1 = pm.decode_estimate([512])
    assert d1.bottleneck in ("memory", "overhead")
    p = pm.prefill_estimate([8192])
    assert p.bottleneck == "compute"


def test_decode_flops_about_2N_per_token():
    cfg = get_config("qwen2.5-7b")
    pm = PerfModel(cfg, TPU_V5E)
    est = pm.decode_estimate([128])  # short ctx: attention negligible
    assert est.flops / (2 * cfg.num_params()) == pytest.approx(1.0, rel=0.15)


def test_prefill_flops_about_2N_tokens():
    cfg = get_config("qwen2.5-7b")
    pm = PerfModel(cfg, TPU_V5E)
    S = 2048
    est = pm.prefill_estimate([S])
    # ~2*N*S (logits computed for one position only, so slightly below 2*N*S
    # with the vocab params included in N; attention adds some back)
    assert est.flops >= 2 * cfg.num_params() * S * 0.75
    assert est.flops <= 2 * cfg.num_params() * S * 1.5


def test_bs_sat_reasonable_and_cached():
    pm = PerfModel(get_config("qwen2.5-7b"), TPU_V5E)
    b1 = pm.compute_saturated_batch(1024)
    b2 = pm.compute_saturated_batch(1024)
    assert b1 == b2
    assert 32 <= b1 <= 2048  # paper: ~300 on A100-class hardware
    # at bs_sat the GEMMs really are compute-bound, below they are not
    assert pm._gemm_compute_bound(b1, 1024)
    if b1 > 1:
        assert not pm._gemm_compute_bound(b1 - 1, 1024)


@given(b=st.integers(1, 256), c=st.integers(1, 16000))
@settings(max_examples=30, deadline=None)
def test_latency_monotone_in_batch_and_context(b, c):
    pm = PerfModel(get_config("qwen2.5-7b"), TPU_V5E)
    l1 = pm.decode_estimate([c] * b).latency
    l2 = pm.decode_estimate([c] * (b + 1)).latency
    l3 = pm.decode_estimate([c + 500] * b).latency
    assert l2 >= l1 - 1e-12
    assert l3 >= l1 - 1e-12


def test_tp_reduces_latency_adds_comm():
    pm1 = PerfModel(get_config("qwen2.5-7b"), TPU_V5E, tp=1)
    pm4 = PerfModel(get_config("qwen2.5-7b"), TPU_V5E, tp=4)
    ctx = [1024] * 64
    assert pm4.decode_estimate(ctx).latency < pm1.decode_estimate(ctx).latency
    det = pm4.decode_estimate(ctx, detail=True)
    assert any(o.kind == "comm" for o in det.ops)


def test_kv_bytes_windowed_vs_full():
    full = PerfModel(get_config("qwen2.5-7b"), TPU_V5E)
    swa = PerfModel(get_config("mixtral-8x22b"), TPU_V5E)
    # windowed arch: kv bytes saturate past the window
    a = swa.kv_bytes([4096])
    b = swa.kv_bytes([500000])
    assert b == pytest.approx(a, rel=1e-9)
    assert full.kv_bytes([8192]) > full.kv_bytes([4096])


def test_ssm_state_constant_in_length():
    pm = PerfModel(get_config("rwkv6-1.6b"), TPU_V5E)
    assert pm.kv_bytes([100]) == pytest.approx(pm.kv_bytes([500000]))
    assert pm.kv_bytes_per_token() == 0.0
    assert pm.state_bytes_fixed() > 0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_all_archs_estimate(arch):
    pm = PerfModel(get_config(arch), TPU_V5E)
    d = pm.decode_estimate([1000] * 8)
    p = pm.prefill_estimate([1000])
    assert d.latency > 0 and np.isfinite(d.latency)
    assert p.latency > 0 and np.isfinite(p.latency)
    assert d.flops > 0 and p.flops > d.flops / 8  # prefill >> decode per req


# ---------------------------------------------------------------------------
# mixed-batch (fused prefill chunk + decode) estimates + chunk budgets
# ---------------------------------------------------------------------------

class TestMixedEstimate:
    def test_single_overhead_and_additive_work(self):
        """A fused step pays ONE static overhead; its work is the sum of the
        prefill-chunk and decode parts."""
        pm = PerfModel(get_config("qwen2.5-7b"), TPU_V5E, tp=4)
        dec = [512] * 16
        m = pm.mixed_estimate(256, 256, dec)
        p = pm.mixed_estimate(256, 256, [])
        d = pm.decode_estimate(dec)
        assert m.overhead == max(pm.hw.O_p, pm.hw.O_d)
        assert m.latency == pytest.approx(
            (p.latency - p.overhead) + (d.latency - d.overhead) + m.overhead,
            rel=1e-9)
        # fusing saves exactly the second dispatch's static overhead
        assert p.latency + d.latency - m.latency == pytest.approx(
            min(pm.hw.O_p, pm.hw.O_d), rel=1e-9)

    def test_degenerate_forms(self):
        pm = PerfModel(get_config("qwen2.5-7b"), TPU_V5E, tp=4)
        d = pm.mixed_estimate(0, 0, [100] * 4)
        assert d.latency == pytest.approx(pm.decode_estimate([100] * 4).latency)
        p = pm.mixed_estimate(128, 128, [])
        assert p.overhead == pm.hw.O_p and p.latency > pm.hw.O_p

    def test_chunk_attention_grows_with_landed_context(self):
        """The same chunk later in the prompt attends to more landed KV."""
        pm = PerfModel(get_config("qwen2.5-7b"), TPU_V5E, tp=4)
        early = pm.mixed_estimate(256, 256, [])
        late = pm.mixed_estimate(256, 4096, [])
        assert late.latency > early.latency


class TestSuggestChunkTokens:
    def test_ridge_point_properties(self):
        pm = PerfModel(get_config("qwen2.5-7b"), TPU_V5E, tp=4)
        sat = pm.prefill_saturation_tokens()
        assert 1 <= sat <= 8192
        t = pm.suggest_chunk_tokens()
        assert t >= 8 and t % 8 == 0
        # a resident decode batch shrinks the leftover budget, never below
        # one bucket
        assert pm.suggest_chunk_tokens([512] * 64) <= max(t, 8)

    def test_slo_cap_enforced(self):
        pm = PerfModel(get_config("qwen2.5-7b"), TPU_V5E, tp=4)
        dec = [1024] * 8
        for slo in (0.005, 0.02, 0.1):
            t = pm.suggest_chunk_tokens(dec, slo=slo)
            assert t >= 0
            if t:
                est = pm.mixed_estimate(t, max(t, 1), dec)
                assert est.latency <= slo * (1 + 1e-9)

    def test_tight_slo_returns_zero(self):
        pm = PerfModel(get_config("qwen2.5-7b"), TPU_V5E, tp=4)
        assert pm.suggest_chunk_tokens([4096] * 8, slo=1e-7) == 0
