"""Engine-level attention-backend parity + decode-batching regressions.

``backend="interpret"`` runs both Pallas kernels (flash prefill + paged
decode attention) in interpret mode end-to-end through the engine;
``backend="ref"`` runs the XLA flash path + the jnp paged oracle. Greedy
decoding over identical weights must produce token-identical output,
including across a prefill interrupt/resume.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.request import Kind, Request
from repro.engine.engine import SamplingParams, ServingEngine, TokenRing, sample_tokens
from repro.models.model import build_model


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2.5-7b").reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _generate(model, params, prompts, n_new, *, backend, interrupt_at=None):
    eng = ServingEngine(model, params, num_pages=64, page_size=8,
                        decode_buckets=(4,), backend=backend)
    reqs = []
    for p in prompts:
        r = Request(Kind.OFFLINE, 0.0, len(p), n_new)
        eng.add_request(r, p)
        if interrupt_at is not None:
            n = [0]

            def preempt():
                n[0] += 1
                return n[0] == interrupt_at

            assert eng.prefill(r.rid, should_preempt=preempt) == "preempted"
            assert eng.prefill(r.rid) == "done"   # resume
        else:
            assert eng.prefill(r.rid) == "done"
        reqs.append(r)
    while any(not r.done for r in reqs):
        eng.decode_step([r.rid for r in reqs if not r.done])
    return [eng.token_buf[r.rid].tolist() for r in reqs], eng


class TestBackendParity:
    def test_interpret_matches_ref(self, setup):
        cfg, model, params = setup
        rng = np.random.RandomState(0)
        prompts = [list(rng.randint(0, cfg.vocab_size, n)) for n in (13, 9)]
        ref, _ = _generate(model, params, prompts, 4, backend="ref")
        out, eng = _generate(model, params, prompts, 4, backend="interpret")
        assert eng.backend == "interpret"
        assert out == ref

    def test_interpret_matches_ref_with_interrupt_resume(self, setup):
        cfg, model, params = setup
        prompt = list(np.random.RandomState(1).randint(0, cfg.vocab_size, 11))
        ref, _ = _generate(model, params, [prompt], 3, backend="ref")
        out, eng = _generate(model, params, [prompt], 3, backend="interpret",
                             interrupt_at=1)
        assert eng.stats.preemptions == 1
        assert out == ref


class TestDecodeBatching:
    def test_oversized_batch_loses_no_requests(self, setup):
        """Regression: len(rids) > max bucket used to silently drop the tail."""
        cfg, model, params = setup
        eng = ServingEngine(model, params, num_pages=64, page_size=8,
                            decode_buckets=(2, 4), backend="ref")
        rng = np.random.RandomState(2)
        reqs = []
        for _ in range(6):   # 6 > max bucket of 4
            p = list(rng.randint(0, cfg.vocab_size, 5))
            r = Request(Kind.OFFLINE, 0.0, len(p), 3)
            eng.add_request(r, p)
            eng.prefill(r.rid)
            reqs.append(r)
        lens_before = {r.rid: len(eng.token_buf[r.rid]) for r in reqs}
        out = eng.decode_step([r.rid for r in reqs])
        assert set(out) == {r.rid for r in reqs}
        for r in reqs:
            assert len(eng.token_buf[r.rid]) == lens_before[r.rid] + 1
        # chunked into ceil(6/4) = 2 bucket-sized steps
        assert eng.stats.decode_steps == 2

    def test_decode_fn_donates_kv_pools(self, setup):
        """The jitted decode step must alias (donate) k_pool/v_pool in/out."""
        cfg, model, params = setup
        eng = ServingEngine(model, params, num_pages=64, page_size=8,
                            decode_buckets=(2,), backend="ref")
        from benchmarks.bench_decode_hotpath import lower_decode_step
        lowered = lower_decode_step(eng, bucket=2, pages=2)
        assert lowered.as_text().count("tf.aliasing_output") >= 2


class TestSampler:
    def test_zero_temperature_is_greedy(self):
        logits = jnp.asarray(np.random.RandomState(0).randn(4, 64), jnp.float32)
        key = jax.random.PRNGKey(0)
        out = sample_tokens(logits, key, jnp.zeros(4), jnp.zeros(4, jnp.int32))
        np.testing.assert_array_equal(np.asarray(out),
                                      np.argmax(np.asarray(logits), -1))

    def test_top_k_one_is_greedy_at_any_temperature(self):
        logits = jnp.asarray(np.random.RandomState(1).randn(4, 64), jnp.float32)
        key = jax.random.PRNGKey(7)
        out = sample_tokens(logits, key, jnp.full(4, 5.0),
                            jnp.ones(4, jnp.int32))
        np.testing.assert_array_equal(np.asarray(out),
                                      np.argmax(np.asarray(logits), -1))

    def test_top_k_respects_support(self):
        logits = jnp.asarray(np.random.RandomState(2).randn(8, 64), jnp.float32)
        top8 = np.argsort(np.asarray(logits), -1)[:, -8:]
        for s in range(5):
            out = np.asarray(sample_tokens(
                logits, jax.random.PRNGKey(s), jnp.full(8, 1.0),
                jnp.full(8, 8, jnp.int32)))
            for b in range(8):
                assert out[b] in top8[b]

    def test_engine_sampled_generation_runs(self, setup):
        """Temperature sampling end-to-end: tokens stay in-vocab and the run
        is reproducible for a fixed engine seed."""
        cfg, model, params = setup

        def run():
            eng = ServingEngine(model, params, num_pages=64, page_size=8,
                                backend="ref",
                                sampling=SamplingParams(temperature=0.8,
                                                        top_k=16, seed=3))
            p = list(np.random.RandomState(3).randint(0, cfg.vocab_size, 7))
            r = Request(Kind.OFFLINE, 0.0, len(p), 5)
            eng.add_request(r, p)
            eng.prefill(r.rid)
            while not r.done:
                eng.decode_step([r.rid])
            return eng.token_buf[r.rid].tolist()

        a, b = run(), run()
        assert a == b
        assert all(0 <= t < cfg.vocab_size for t in a)


class TestCrossEngineMigration:
    def test_mid_decode_migration_token_parity(self, setup):
        """A request migrated relaxed→strict mid-decode must produce the
        identical token sequence as one decoded on a single engine (the
        pool runtime's KV movement is bit-transparent)."""
        cfg, model, params = setup
        prompt = list(np.random.RandomState(4).randint(0, cfg.vocab_size, 12))
        ref, _ = _generate(model, params, [prompt], 8, backend="ref")

        a = ServingEngine(model, params, num_pages=64, page_size=8,
                          decode_buckets=(4,), backend="ref")
        b = ServingEngine(model, params, num_pages=64, page_size=8,
                          decode_buckets=(4,), backend="ref", kernels_from=a)
        r = Request(Kind.OFFLINE, 0.0, len(prompt), 8)
        a.add_request(r, prompt)
        assert a.prefill(r.rid) == "done"
        for _ in range(3):                      # decode part-way on engine A
            a.decode_step([r.rid])
        k, v, n = a.migrate_out(r.rid)
        b.migrate_in(r.rid, r, a.token_buf[r.rid], k, v, n)
        while not r.done:                       # finish on engine B
            b.decode_step([r.rid])
        assert b.token_buf[r.rid].tolist() == ref[0]

    def test_migration_after_interrupted_prefill_parity(self, setup):
        """Interrupt-resume prefill, then migrate mid-decode: still token-
        identical (partial-prefill KV segments survive the engine hop)."""
        cfg, model, params = setup
        prompt = list(np.random.RandomState(5).randint(0, cfg.vocab_size, 15))
        ref, _ = _generate(model, params, [prompt], 6, backend="ref")

        a = ServingEngine(model, params, num_pages=64, page_size=8,
                          decode_buckets=(4,), backend="ref")
        b = ServingEngine(model, params, num_pages=64, page_size=8,
                          decode_buckets=(4,), backend="ref", kernels_from=a)
        r = Request(Kind.OFFLINE, 0.0, len(prompt), 6)
        a.add_request(r, prompt)
        n_polls = [0]

        def preempt():
            n_polls[0] += 1
            return n_polls[0] == 1

        assert a.prefill(r.rid, should_preempt=preempt) == "preempted"
        assert a.prefill(r.rid) == "done"
        a.decode_step([r.rid])
        k, v, n = a.migrate_out(r.rid)
        b.migrate_in(r.rid, r, a.token_buf[r.rid], k, v, n)
        while not r.done:
            b.decode_step([r.rid])
        assert b.token_buf[r.rid].tolist() == ref[0]


class TestTokenRing:
    def test_list_semantics(self):
        ring = TokenRing([1, 2, 3], capacity=4)
        ring.append(4)
        ring.append(5)   # forces growth past capacity
        assert ring == [1, 2, 3, 4, 5]
        assert list(ring) == [1, 2, 3, 4, 5]
        assert ring[0] == 1 and ring[-1] == 5
        assert ring[1:3] == [2, 3]
        assert len(ring) == 5
