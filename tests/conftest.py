import sys
import types

import jax
import pytest

# Smoke tests and benches run on the single real CPU device; ONLY the
# dry-run subprocess sets --xla_force_host_platform_device_count=512.

jax.config.update("jax_threefry_partitionable", True)

# ---------------------------------------------------------------------------
# Graceful degrade when `hypothesis` is absent (see requirements-dev.txt):
# install a stub module whose @given marks the test skipped, so the suite
# still collects and the non-property-based tests run.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:
    def _given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install -r "
                       "requirements-dev.txt)")(fn)
        return deco

    def _settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco

    class _Strategy:
        """Stand-in accepted anywhere a strategy is built/combined."""
        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    _st = types.ModuleType("hypothesis.strategies")
    _st.__getattr__ = lambda name: _Strategy()

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
