import jax
import pytest

# Smoke tests and benches run on the single real CPU device; ONLY the
# dry-run subprocess sets --xla_force_host_platform_device_count=512.

jax.config.update("jax_threefry_partitionable", True)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
