"""Per-architecture smoke tests (assignment deliverable f).

Each assigned architecture instantiates a REDUCED variant of the same family
(2 layers, d_model <= 512, <= 4 experts) and runs one forward + one train
step on CPU, asserting output shapes and the absence of NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models.model import build_model

B, S = 2, 32


def _batch(cfg, rng, seq=S):
    tokens = jax.random.randint(rng, (B, seq), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.frontend == "vision":
        batch["frontend_embeds"] = jax.random.normal(
            rng, (B, cfg.num_frontend_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        batch["frontend_embeds"] = jax.random.normal(
            rng, (B, 64, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_forward_and_decode(arch, rng):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.num_experts <= 4
    model = build_model(cfg, remat=False)
    params = model.init(rng)
    batch = _batch(cfg, rng)
    logits, cache = model.prefill(params, batch, cache_len=S + 8)
    assert logits.shape == (B, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache2 = model.decode_step(params, nxt, cache)
    assert logits2.shape == (B, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits2, np.float32)))
    assert int(cache2["pos"][0]) == int(cache["pos"][0]) + 1


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_train_step(arch, rng):
    from repro.training.optimizer import AdamWConfig, init_opt_state
    from repro.training.train_loop import make_train_step

    cfg = get_config(arch).reduced()
    model = build_model(cfg, remat=True)
    params = model.init(rng)
    batch = _batch(cfg, rng)
    labels = jnp.concatenate(
        [batch["tokens"][:, 1:], -jnp.ones((B, 1), jnp.int32)], axis=1)
    batch["labels"] = labels
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3, warmup_steps=1,
                                                      total_steps=10)))
    opt = init_opt_state(params)
    params2, opt2, metrics = step(params, opt, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    changed = any(
        not np.array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert changed
