"""Multi-step decode horizons (ISSUE 5).

* **K-step vs K serial parity**: one ``decode_horizon`` dispatch must emit
  bit-identical tokens to K serial ``decode_step`` calls — greedy AND
  seeded temperature/top-k sampling — while performing exactly one
  device->host sync (``EngineStats.host_syncs``).
* **Early exit**: a request hitting ``max_new_tokens`` mid-horizon emits no
  extra tokens, frees its pages, and leaves co-batched requests exact.
* **Page claim-ahead**: horizons crossing page boundaries never run off the
  request's block table.
* **Roofline choice**: ``PerfModel.suggest_decode_horizon`` amortizes the
  dispatch overhead and respects the SLO/preemption-latency bounds;
  ``horizon_estimate`` charges ONE static overhead per horizon.
* **Runtime**: virtual-clock replays with ``decode_horizon="auto"`` stay
  bit-deterministic, keep chunk-boundary preemption intact, never run
  horizons on the strict pool, and lose no offline throughput.
"""
import jax
import numpy as np
import pytest

from repro.cluster.runtime import PoolRuntime, VirtualClock, replay_hw
from repro.configs import get_config
from repro.core import scheduling as sch
from repro.core.perf_model import PerfModel
from repro.core.request import Kind, Phase, Request
from repro.data import traces as tr
from repro.engine.engine import SamplingParams, ServingEngine
from repro.models.model import build_model

SLO_TTFT = 1.0
SLO_TPOT = 0.030


@pytest.fixture(scope="module")
def built():
    cfg = get_config("qwen2.5-7b").reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params, [None]   # last slot: shared kernel donor


def _prompts(cfg, seed, lens):
    rng = np.random.RandomState(seed)
    return [list(rng.randint(0, cfg.vocab_size, n)) for n in lens]


def _engine_with(model, params, prompts, output_len, sampling=None):
    eng = ServingEngine(model, params, num_pages=64, page_size=8,
                        sampling=sampling)
    reqs = []
    for p in prompts:
        r = Request(Kind.OFFLINE, 0.0, len(p), output_len)
        eng.add_request(r, p)
        eng.prefill(r.rid)
        reqs.append(r)
    return eng, reqs


class TestHorizonParity:
    @pytest.mark.parametrize("sampling", [
        None, SamplingParams(temperature=0.8, top_k=16, seed=3)],
        ids=["greedy", "sampled"])
    def test_k_step_horizon_matches_k_serial_steps(self, built, sampling):
        cfg, model, params, _ = built
        prompts = _prompts(cfg, 0, (13, 21, 7))
        K = 5
        eng_s, reqs_s = _engine_with(model, params, prompts, 20, sampling)
        for _ in range(K):
            eng_s.decode_step([r.rid for r in reqs_s])
        eng_h, reqs_h = _engine_with(model, params, prompts, 20, sampling)
        syncs0 = eng_h.stats.host_syncs
        out = eng_h.decode_horizon([r.rid for r in reqs_h], K)
        # exactly ONE device->host sync for the whole horizon
        assert eng_h.stats.host_syncs == syncs0 + 1
        assert eng_h.stats.horizon_steps == K
        for rs, rh in zip(reqs_s, reqs_h):
            assert (eng_s.token_buf[rs.rid].tolist()
                    == eng_h.token_buf[rh.rid].tolist())
            assert len(out[rh.rid]) == K

    def test_finish_mid_horizon_emits_no_extra_tokens(self, built):
        cfg, model, params, _ = built
        prompts = _prompts(cfg, 1, (11, 16))
        # 3 outputs total: 1 from prefill + 2 decode steps, horizon of 8
        eng_s, reqs_s = _engine_with(model, params, prompts, 3)
        while any(not r.done for r in reqs_s):
            eng_s.decode_step([r.rid for r in reqs_s if not r.done])
        eng_h, reqs_h = _engine_with(model, params, prompts, 3)
        out = eng_h.decode_horizon([r.rid for r in reqs_h], 8)
        for rs, rh in zip(reqs_s, reqs_h):
            assert rh.generated == rh.output_len == 3
            assert rh.phase == Phase.FINISHED
            assert len(out[rh.rid]) == 2          # masked past max_new_tokens
            assert (eng_s.token_buf[rs.rid].tolist()
                    == eng_h.token_buf[rh.rid].tolist())
            assert rh.rid not in eng_h.cache.tables   # pages freed

    def test_mixed_remaining_lengths_stay_exact(self, built):
        """A short-output request going inactive mid-horizon must not
        perturb the rows still decoding (its masked writes land in the
        trash page, not in live state)."""
        cfg, model, params, _ = built
        prompts = _prompts(cfg, 2, (9, 14))
        eng_s, reqs_s = _engine_with(model, params, prompts, 12)
        reqs_s[0].output_len = 2                  # finishes after 1 decode
        while any(not r.done for r in reqs_s):
            eng_s.decode_step([r.rid for r in reqs_s if not r.done])
        eng_h, reqs_h = _engine_with(model, params, prompts, 12)
        reqs_h[0].output_len = 2
        eng_h.decode_horizon([r.rid for r in reqs_h], 6)
        while any(not r.done for r in reqs_h):
            eng_h.decode_horizon([r.rid for r in reqs_h if not r.done], 6)
        for rs, rh in zip(reqs_s, reqs_h):
            assert (eng_s.token_buf[rs.rid].tolist()
                    == eng_h.token_buf[rh.rid].tolist())

    def test_page_claim_ahead_across_boundaries(self, built):
        """A horizon whose writes cross page boundaries claims the pages
        BEFORE the dispatch and stays token-exact."""
        cfg, model, params, _ = built
        prompts = _prompts(cfg, 3, (8,))          # exactly one full page
        eng_s, reqs_s = _engine_with(model, params, prompts, 20)
        for _ in range(18):
            eng_s.decode_step([reqs_s[0].rid])
        eng_h, reqs_h = _engine_with(model, params, prompts, 20)
        r = reqs_h[0]
        pages_before = len(eng_h.cache.tables[r.rid])
        eng_h.decode_horizon([r.rid], 18)         # crosses 2+ page boundaries
        assert len(eng_h.cache.tables[r.rid]) > pages_before
        assert (eng_s.token_buf[reqs_s[0].rid].tolist()
                == eng_h.token_buf[r.rid].tolist())

    def test_horizon_trace_reuse(self, built):
        """Repeated horizons at the same (bucket, pages, K) reuse one
        compiled function."""
        cfg, model, params, _ = built
        # prompt sized so both horizons land in the same pad_pages bucket
        prompts = _prompts(cfg, 4, (20, 20))
        eng, reqs = _engine_with(model, params, prompts, 30)
        eng.decode_horizon([r.rid for r in reqs], 4)
        n = len(eng._horizon_fns)
        eng.decode_horizon([r.rid for r in reqs], 4)
        assert len(eng._horizon_fns) == n

    def test_horizon_donates_both_pools(self, built):
        """The lowered horizon scan must alias both donated pools with zero
        surviving full-pool copies (same proof as the decode step)."""
        from benchmarks.bench_decode_hotpath import (donation_report,
                                                     lower_horizon_step)
        cfg, model, params, _ = built
        eng = ServingEngine(model, params, num_pages=64, page_size=8)
        rep = donation_report(lower_horizon_step(eng, bucket=4, pages=4,
                                                 steps=4),
                              eng.cache.k_pool.shape)
        assert rep["donated_args"] == 2
        assert rep["full_pool_copies"] == 0


class TestSuggestDecodeHorizon:
    PM = PerfModel(get_config("qwen2.5-7b").reduced(), replay_hw())

    def test_amortizes_dispatch_overhead(self):
        # small batches are overhead-dominated -> multi-step horizons
        assert self.PM.suggest_decode_horizon([32] * 2) > 1
        # a measured host overhead far above O_d demands a longer horizon
        k_plain = self.PM.suggest_decode_horizon([32] * 4)
        k_hosty = self.PM.suggest_decode_horizon(
            [32] * 4, dispatch_overhead=50 * self.PM.hw.O_d)
        assert k_hosty >= k_plain

    def test_saturated_batches_stay_single_step(self):
        # large batches amortize O_d already — fusing buys nothing
        assert self.PM.suggest_decode_horizon([512] * 64) == 1

    def test_respects_preemption_latency_bound(self):
        ctx = [32] * 2
        k = self.PM.suggest_decode_horizon(ctx, preempt_latency=0.25)
        assert self.PM.horizon_estimate(ctx, k).latency <= 0.25 * (1 + 1e-9)
        # a bound below even one step can't improve on today's behavior
        assert self.PM.suggest_decode_horizon(ctx, preempt_latency=1e-9) == 1

    def test_horizon_estimate_charges_one_overhead(self):
        ctx = [64] * 4
        K = 8
        one = self.PM.decode_estimate(ctx)
        hz = self.PM.horizon_estimate(ctx, K)
        # K fused steps cost less than K serial dispatches but more than 1
        assert one.latency < hz.latency
        assert hz.overhead == self.PM.hw.O_d
        # vs K serial steps at the SAME growing contexts, the saving is
        # exactly the K-1 amortized dispatch overheads (the midpoint form
        # is exact while attention is linear in context)
        serial = sum(self.PM.decode_estimate([c + t for c in ctx]).latency
                     for t in range(K))
        saved = (K - 1) * self.PM.hw.O_d
        assert hz.latency == pytest.approx(serial - saved, rel=1e-9)


class TestHorizonScheduling:
    PM = TestSuggestDecodeHorizon.PM

    def _reqs(self, kind, n, ctx=32, out=16):
        return [Request(kind, 0.0, ctx, out) for _ in range(n)]

    def test_offline_relaxed_round_gets_horizon(self):
        batch = self._reqs(Kind.OFFLINE, 2)
        k = sch.decode_horizon_steps(batch, self.PM, requested="auto",
                                     preempt_latency=0.25)
        assert k > 1

    def test_strict_and_queued_online_clamp(self):
        batch = self._reqs(Kind.OFFLINE, 2)
        assert sch.decode_horizon_steps(batch, self.PM, requested="auto",
                                        strict=True) == 1
        assert sch.decode_horizon_steps(batch, self.PM, requested="auto",
                                        queued_online=True) == 1

    def test_online_resident_clamps(self):
        batch = self._reqs(Kind.OFFLINE, 2) + self._reqs(Kind.ONLINE, 1)
        assert sch.decode_horizon_steps(batch, self.PM,
                                        requested="auto") == 1

    def test_remaining_output_caps_horizon(self):
        batch = self._reqs(Kind.OFFLINE, 2, out=3)
        for r in batch:
            r.generated = 1
        assert sch.decode_horizon_steps(batch, self.PM, requested=16) <= 2

    def test_requested_one_is_identity(self):
        batch = self._reqs(Kind.OFFLINE, 4)
        for req in (1, None, 0):
            assert sch.decode_horizon_steps(batch, self.PM,
                                            requested=req) == 1

    def test_plan_carries_horizon_with_and_without_chunk(self):
        decode = self._reqs(Kind.OFFLINE, 4)
        plan = sch.token_budget_schedule([], decode, None, 0, self.PM,
                                         relaxed_cap=8, horizon=4)
        assert plan.horizon == 4 and plan.chunk_tokens == 0
        assert plan.total_tokens == 4 * len(plan.decode)
        # a riding chunk no longer drops the horizon: the relaxed round
        # becomes one fused mixed-horizon dispatch whose budget covers
        # decode x K + chunk
        pf = Request(Kind.OFFLINE, 0.0, 64, 8)
        plan = sch.token_budget_schedule([], decode, pf, 64, self.PM,
                                         relaxed_cap=8, horizon=4, bucket=8)
        assert plan.chunk_tokens > 0 and plan.horizon > 1
        # ... clamped so every sub-chunk carries >= one bucket of prefill
        assert plan.horizon <= max(plan.chunk_tokens // 8, 1)
        assert plan.total_tokens == (len(plan.decode) * plan.horizon
                                     + plan.chunk_tokens)
        # tiny chunk: the clamp collapses K to chunk // bucket
        plan = sch.token_budget_schedule([], decode, pf, 8, self.PM,
                                         relaxed_cap=8, horizon=4, bucket=8)
        assert plan.chunk_tokens == 8 and plan.horizon == 1
        # latency-strict chunked rounds keep single-step fused semantics
        plan = sch.token_budget_schedule([], decode, pf, 64, self.PM,
                                         relaxed_cap=8, horizon=4, bucket=8,
                                         slo=10.0)
        assert plan.chunk_tokens > 0 and plan.horizon == 1

    def test_split_chunk_invariants(self):
        for chunk, steps in [(16, 4), (17, 4), (13, 16), (1, 8), (64, 5)]:
            subs = sch.split_chunk(chunk, steps)
            assert sum(subs) == chunk
            assert min(subs) >= 1
            assert max(subs) - min(subs) <= 1
            assert len(subs) == min(steps, chunk)


# ---------------------------------------------------------------------------
# pool-runtime integration under the virtual clock
# ---------------------------------------------------------------------------

def _replay(built, policy, *, seed=0, decode_horizon="auto", n_offline=60,
            offline_qps=20.0, online_qps=1.2, duration=6.0, max_output=12):
    cfg, model, params, donor = built
    rt = PoolRuntime(cfg, policy=policy, n_strict=1, n_relaxed=2,
                     clock=VirtualClock(), backend="ref", num_pages=256,
                     page_size=8, slo_ttft=SLO_TTFT, slo_tpot=SLO_TPOT,
                     hw=replay_hw(), seed=seed, model=model, params=params,
                     decode_horizon=decode_horizon, kernels_from=donor[0])
    donor[0] = donor[0] or rt.kernel_donor
    online = tr.online_trace("ooc", duration=duration, mean_qps=online_qps,
                             seed=seed)
    offline = tr.with_uniform_qps(
        tr.offline_requests(n_offline, seed=seed + 1), offline_qps)
    summary = rt.run(online, offline, duration=duration, max_prompt=48,
                     max_output=max_output, drain=False)
    return summary, rt


class TestRuntimeHorizons:
    @pytest.fixture(scope="class")
    def auto_runs(self, built):
        return [_replay(built, "ooco", decode_horizon="auto")
                for _ in range(2)]

    def test_replay_bit_deterministic_with_horizons(self, auto_runs):
        (m1, rt1), (m2, rt2) = auto_runs
        assert m1 == m2
        assert rt1.finished_signature() == rt2.finished_signature()
        assert m1["horizon_steps"] > 0      # horizons actually fired
        assert m1["horizon_rounds"] > 0

    def test_strict_pool_never_runs_horizons(self, auto_runs):
        _, rt = auto_runs[0]
        assert all(s.engine.stats.horizon_steps == 0 for s in rt.strict_pool)
        assert any(s.engine.stats.horizon_steps > 0 for s in rt.relaxed_pool)

    def test_no_throughput_or_slo_loss_vs_single_step(self, built, auto_runs):
        m_auto, _ = auto_runs[0]
        m_one, _ = _replay(built, "ooco", decode_horizon=1)
        assert (m_auto["offline_tokens_per_s"]
                >= m_one["offline_tokens_per_s"] * (1 - 1e-9))
        assert (m_auto["online_slo_attainment"]
                >= m_one["online_slo_attainment"])
        # fewer host syncs for the same trace: the horizons' whole point
        assert m_auto["host_syncs"] < m_one["host_syncs"]
        assert m_one["horizon_steps"] == 0

    def test_chunk_boundary_preemption_unchanged_with_horizons(self, built):
        """§3.4.1: an online arrival mid-prefill still pauses the offline
        prefill at the next chunk boundary when horizons are active on the
        relaxed pool — and still re-runs no layer."""
        cfg, model, params, donor = built
        rt = PoolRuntime(cfg, policy="ooco", n_strict=1, n_relaxed=1,
                         clock=VirtualClock(), backend="ref", num_pages=128,
                         page_size=8, slo_ttft=SLO_TTFT, slo_tpot=SLO_TPOT,
                         hw=replay_hw(), seed=0, model=model, params=params,
                         chunk_tokens=8, decode_horizon="auto",
                         kernels_from=donor[0])
        offline = [tr.TraceRequest(0.0, 48, 4)]
        online = [tr.TraceRequest(0.005, 16, 4)]   # mid-prefill arrival
        m = rt.run(online, offline, duration=2.0, max_prompt=48, max_output=4)
        assert m["chunk_preemptions"] >= 1
        assert m["online_finished"] == 1 and m["offline_finished"] == 1
        assert m["recompute_tokens"] == 0
