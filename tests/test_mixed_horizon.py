"""Fused mixed horizons (PR 10): K decode iterations + K prefill sub-chunk
slices in ONE jitted ``lax.scan`` dispatch.

* **Parity**: ``mixed_horizon(rids, prid, chunk, K)`` must emit
  bit-identical token streams to K serial ``mixed_step`` calls over the
  same ``split_chunk`` slices — greedy AND seeded temperature/top-k
  sampling — with exactly one device->host sync per horizon.
* **Early exit**: a decode request hitting ``max_new_tokens`` mid-horizon
  emits no extra tokens and leaves co-batched requests and the riding
  chunk exact.
* **Pause/resume**: stopping at a horizon boundary and continuing with
  serial ``mixed_step`` calls recomputes nothing and changes no tokens.
* **Prefix-cache warm starts**: a request whose prompt prefix is already
  resident lands only the cold suffix through the fused path and still
  matches whole-prompt reference generation.
* **Donation**: the lowered fused scan aliases both KV pools
  (``tf.aliasing_output`` x2) and the optimized HLO contains no
  full-pool-shaped copy.
* **Roofline choice**: ``PerfModel.suggest_mixed_horizon`` fuses on
  overhead-dominated hardware, stays serial when per-sub-chunk weight
  streaming would cost more than the amortized dispatch overhead, and
  shrinks K under the §3.4.1 preemption bound (halved with online
  arrivals queued).
* **Budget property** (hypothesis): relaxed chunked plans with
  ``horizon > 1`` never exceed the token budget and never produce a
  sub-chunk smaller than one bucket.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.cluster.runtime import PoolRuntime, VirtualClock, replay_hw
from repro.configs import get_config
from repro.core import scheduling as sch
from repro.core.perf_model import PerfModel
from repro.core.request import Kind, Request
from repro.data import traces as tr
from repro.engine.engine import SamplingParams, ServingEngine
from repro.models.model import build_model


@pytest.fixture(scope="module")
def built():
    cfg = get_config("qwen2.5-7b").reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _ref_generate(model, params, prompt, n_new):
    toks = list(prompt)
    logits, cache = model.prefill(
        params, {"tokens": jnp.asarray([prompt], jnp.int32)},
        cache_len=len(prompt) + n_new)
    toks.append(int(jnp.argmax(logits, -1)[0]))
    for _ in range(n_new - 1):
        logits, cache = model.decode_step(
            params, jnp.asarray([toks[-1]], jnp.int32), cache)
        toks.append(int(jnp.argmax(logits, -1)[0]))
    return toks


def _setup_engine(cfg, model, params, *, dec_specs, pf_len, pf_out=6,
                  sampling=None, seed=3, overrides=(), prefix_cache=False):
    """Engine with resident decode requests (prompt_len, output_len specs)
    plus one un-prefilled request for the chunked path. ``overrides`` are
    (slot, temperature, top_k) per-request sampling overrides; slot == -1
    targets the prefill request."""
    eng = ServingEngine(model, params, num_pages=256, page_size=8,
                        sampling=sampling, prefix_cache=prefix_cache)
    rng = np.random.RandomState(seed)
    reqs = []
    for n, out in dec_specs:
        r = Request(Kind.OFFLINE, 0.0, n, out)
        eng.add_request(r, list(rng.randint(0, cfg.vocab_size, n)))
        eng.prefill(r.rid)
        reqs.append(r)
    pf = Request(Kind.OFFLINE, 0.0, pf_len, pf_out)
    eng.add_request(pf, list(rng.randint(0, cfg.vocab_size, pf_len)))
    for slot, temp, top_k in overrides:
        eng.set_sampling((pf if slot == -1 else reqs[slot]).rid, temp, top_k)
    return eng, reqs, pf


def _drive_fused(eng, reqs, pf, chunk, K):
    """Advance the prefill to completion via fused horizons, then drain
    decode. Returns syncs used per horizon dispatch."""
    per_dispatch = []
    while pf.prefill_tokens_done < pf.prompt_len:
        active = [r.rid for r in reqs if not r.done]
        s0 = eng.stats.host_syncs
        eng.mixed_horizon(active, pf.rid, chunk, K)
        per_dispatch.append(eng.stats.host_syncs - s0)
    live = [r for r in reqs + [pf] if not r.done]
    while live:
        eng.decode_step([r.rid for r in live])
        live = [r for r in live if not r.done]
    return per_dispatch


def _drive_serial(eng, reqs, pf, chunk, K):
    """The serial reference: the SAME sub-chunk slices as one fused
    horizon, one ``mixed_step`` dispatch (and one sync) each."""
    while pf.prefill_tokens_done < pf.prompt_len:
        c = min(chunk, pf.prompt_len - pf.prefill_tokens_done)
        for s in sch.split_chunk(c, min(K, c)):
            eng.mixed_step([r.rid for r in reqs if not r.done], pf.rid, s)
    live = [r for r in reqs + [pf] if not r.done]
    while live:
        eng.decode_step([r.rid for r in live])
        live = [r for r in live if not r.done]


class TestMixedHorizonParity:
    DEC = ((13, 24), (21, 2))   # second rid finishes mid-horizon (early exit)

    def _streams(self, eng, reqs, pf):
        return [eng.token_buf[r.rid][:] for r in reqs + [pf]]

    def test_greedy_parity_early_exit_one_sync(self, built):
        cfg, model, params = built
        fused, f_reqs, f_pf = _setup_engine(cfg, model, params,
                                            dec_specs=self.DEC, pf_len=29)
        serial, s_reqs, s_pf = _setup_engine(cfg, model, params,
                                             dec_specs=self.DEC, pf_len=29)
        per_dispatch = _drive_fused(fused, f_reqs, f_pf, 13, 4)
        _drive_serial(serial, s_reqs, s_pf, 13, 4)
        assert self._streams(fused, f_reqs, f_pf) == \
            self._streams(serial, s_reqs, s_pf)
        assert per_dispatch == [1] * len(per_dispatch)  # ONE sync/horizon
        assert fused.stats.dispatches_by_kind["mixed_horizon"] == \
            len(per_dispatch)
        assert serial.stats.dispatches_by_kind["mixed_horizon"] == 0
        assert fused.stats.host_syncs < serial.stats.host_syncs

    def test_sampled_parity(self, built):
        cfg, model, params = built
        sp = SamplingParams(temperature=0.9, top_k=7, seed=11)
        ov = ((0, 0.6, 3), (-1, 1.1, 9))   # per-request incl. prefill rid
        fused, f_reqs, f_pf = _setup_engine(
            cfg, model, params, dec_specs=self.DEC, pf_len=29, sampling=sp,
            overrides=ov)
        serial, s_reqs, s_pf = _setup_engine(
            cfg, model, params, dec_specs=self.DEC, pf_len=29, sampling=sp,
            overrides=ov)
        _drive_fused(fused, f_reqs, f_pf, 13, 4)
        _drive_serial(serial, s_reqs, s_pf, 13, 4)
        assert self._streams(fused, f_reqs, f_pf) == \
            self._streams(serial, s_reqs, s_pf)
        # the fused path reserved exactly the K keys the serial steps used
        assert fused._sample_step == serial._sample_step

    def test_chunk_only_horizon(self, built):
        cfg, model, params = built
        fused, _, f_pf = _setup_engine(cfg, model, params, dec_specs=(),
                                       pf_len=23)
        serial, _, s_pf = _setup_engine(cfg, model, params, dec_specs=(),
                                        pf_len=23)
        per_dispatch = _drive_fused(fused, [], f_pf, 12, 4)
        _drive_serial(serial, [], s_pf, 12, 4)
        assert fused.token_buf[f_pf.rid][:] == serial.token_buf[s_pf.rid][:]
        assert per_dispatch == [1] * len(per_dispatch)

    def test_pause_resume_zero_recompute(self, built):
        cfg, model, params = built
        eng, reqs, pf = _setup_engine(cfg, model, params,
                                      dec_specs=((13, 24),), pf_len=30)
        serial, s_reqs, s_pf = _setup_engine(cfg, model, params,
                                             dec_specs=((13, 24),),
                                             pf_len=30)
        eng.mixed_horizon([reqs[0].rid], pf.rid, 12, 3)   # one horizon
        assert eng.prefill_progress(pf.rid) == 12         # paused mid-prompt
        assert pf.recompute_tokens == 0
        # resume with SERIAL steps over the same slices: no recompute, no
        # token change — the horizon boundary is a clean chunk boundary
        while pf.prefill_tokens_done < pf.prompt_len:
            c = min(12, pf.prompt_len - pf.prefill_tokens_done)
            for s in sch.split_chunk(c, min(3, c)):
                eng.mixed_step([reqs[0].rid], pf.rid, s)
        assert pf.recompute_tokens == 0
        live = [r for r in reqs + [pf] if not r.done]
        while live:
            eng.decode_step([r.rid for r in live])
            live = [r for r in live if not r.done]
        _drive_serial(serial, s_reqs, s_pf, 12, 3)
        for a, b in zip(reqs + [pf], s_reqs + [s_pf]):
            assert eng.token_buf[a.rid][:] == serial.token_buf[b.rid][:]

    def test_prefix_cache_warm_start(self, built):
        cfg, model, params = built
        eng, _, pf_a = _setup_engine(cfg, model, params, dec_specs=(),
                                     pf_len=24, pf_out=4, prefix_cache=True)
        prompt_a = eng.token_buf[pf_a.rid][: pf_a.prompt_len]
        _drive_fused(eng, [], pf_a, 8, 4)        # completion publishes pages
        rng = np.random.RandomState(9)
        prompt_b = prompt_a + list(rng.randint(0, cfg.vocab_size, 8))
        pf_b = Request(Kind.OFFLINE, 0.0, len(prompt_b), 4)
        eng.add_request(pf_b, prompt_b)
        assert eng.claim_prefix(pf_b.rid) > 0    # warm prefix resident
        _drive_fused(eng, [], pf_b, 8, 4)        # only the suffix is cold
        assert eng.token_buf[pf_b.rid][:] == \
            _ref_generate(model, params, prompt_b, 4)

    def test_fused_scan_donates_both_pools(self, built):
        cfg, model, params = built
        eng = ServingEngine(model, params, num_pages=64, page_size=8)
        fn = eng._mixed_horizon_fn(2, 8, 8, 8, 4)
        zi = jnp.zeros((2,), jnp.int32)
        lowered = fn.lower(
            eng.params, zi, zi, jnp.zeros((2, 8), jnp.int32),
            eng.cache.k_pool, eng.cache.v_pool, jnp.ones((2,), jnp.int32),
            jnp.zeros((4, 8), jnp.int32), jnp.zeros((4, 2), jnp.int32),
            jnp.zeros((8,), jnp.int32), jax.random.PRNGKey(0), jnp.int32(1),
            jnp.zeros((3,), jnp.float32), jnp.zeros((3,), jnp.int32))
        assert lowered.as_text().count("tf.aliasing_output") == 2
        dims = ",".join(map(str, eng.cache.k_pool.shape))
        hlo = lowered.compile().as_text()
        assert not [ln for ln in hlo.splitlines()
                    if "copy(" in ln and f"[{dims}]" in ln]


class TestSuggestMixedHorizon:
    CFG = get_config("qwen2.5-7b").reduced()
    PM_DC = PerfModel(CFG, replay_hw("v5e"))   # overhead-dominated
    PM_CPU = PerfModel(CFG, replay_hw())       # streaming-dominated

    def test_overhead_dominated_fuses(self):
        k = self.PM_DC.suggest_mixed_horizon(8, 72, [64] * 2,
                                             preempt_latency=0.5,
                                             max_horizon=16)
        assert k == 8   # k <= chunk_tokens always

    def test_streaming_dominated_stays_serial(self):
        # on cpu-scale hw a sub-chunk's weight stream costs far more than
        # the dispatch overhead it amortizes: the throughput argmax keeps
        # the round serial
        assert self.PM_CPU.suggest_mixed_horizon(
            48, 112, [64] * 8, preempt_latency=0.5, max_horizon=16) == 1

    def test_preemption_bound_shrinks(self):
        loose = self.PM_DC.suggest_mixed_horizon(
            8, 72, [64] * 2, preempt_latency=0.5, max_horizon=16)
        tight = self.PM_DC.suggest_mixed_horizon(
            8, 72, [64] * 2, preempt_latency=0.02, max_horizon=16)
        assert tight < loose

    def test_queued_online_shrinks(self):
        base = self.PM_DC.suggest_mixed_horizon(
            8, 72, [64] * 2, preempt_latency=0.04, max_horizon=16)
        queued = self.PM_DC.suggest_mixed_horizon(
            8, 72, [64] * 2, preempt_latency=0.04, queued_online=True,
            max_horizon=16)
        assert queued < base   # half the preemption budget -> smaller K

    def test_no_decode_returns_one(self):
        assert self.PM_DC.suggest_mixed_horizon(48, 112, [],
                                                max_horizon=16) == 1

    def test_chunkless_delegates_to_decode_horizon(self):
        assert self.PM_CPU.suggest_mixed_horizon(
            0, 0, [64] * 4, preempt_latency=0.5, max_horizon=8) == \
            self.PM_CPU.suggest_decode_horizon(
                [64] * 4, preempt_latency=0.5, max_horizon=8)

    def test_caps(self):
        assert self.PM_DC.suggest_mixed_horizon(
            3, 67, [64] * 8, preempt_latency=0.5, max_horizon=16) <= 3
        assert self.PM_DC.suggest_mixed_horizon(
            8, 72, [64] * 2, preempt_latency=0.5, max_horizon=2) <= 2


class TestBudgetSplitProperty:
    PM = TestSuggestMixedHorizon.PM_CPU

    @given(remaining=st.integers(1, 256), budget=st.integers(1, 128),
           horizon=st.integers(1, 16), n_dec=st.integers(0, 8))
    @settings(max_examples=60, deadline=None)
    def test_budget_and_bucket_floor(self, remaining, budget, horizon,
                                     n_dec):
        decode = [Request(Kind.OFFLINE, 0.0, 32, 16) for _ in range(n_dec)]
        pf = Request(Kind.OFFLINE, 0.0, remaining, 8)
        plan = sch.token_budget_schedule([], decode, pf, remaining, self.PM,
                                         relaxed_cap=8, budget_tokens=budget,
                                         horizon=horizon, bucket=8)
        chunk = plan.chunk_tokens
        assert chunk <= remaining
        assert chunk <= max(budget, 8)   # relaxed floor is one bucket
        assert plan.horizon <= max(horizon, 1)
        assert plan.total_tokens == len(plan.decode) * plan.horizon + chunk
        if plan.horizon > 1:
            subs = sch.split_chunk(chunk, plan.horizon)
            assert sum(subs) == chunk and len(subs) == plan.horizon
            assert min(subs) >= 8        # no sub-chunk below one bucket


class TestRuntimeMixedHorizon:
    def test_datacenter_replay_deterministic_and_counted(self, built):
        """Under replay_hw('v5e') the ooco runtime fires fused
        mixed-horizon rounds; two replays with the same seed are
        bit-identical and the summary exposes both the round counter and
        per-kind dispatch counts."""
        cfg, model, params = built
        outs = []
        donor = None
        for _ in range(2):
            rt = PoolRuntime(cfg, policy="ooco", n_strict=1, n_relaxed=2,
                             clock=VirtualClock(), backend="ref",
                             num_pages=256, page_size=8, slo_ttft=2.0,
                             slo_tpot=0.06, hw=replay_hw("v5e"), seed=0,
                             model=model, params=params,
                             chunk_tokens="auto", decode_horizon="auto",
                             kernels_from=donor)
            donor = donor or rt.kernel_donor
            online = tr.online_trace("ooc", duration=4.0, mean_qps=8.0,
                                     seed=0)
            offline = tr.with_uniform_qps(
                tr.offline_requests(400, seed=1), 150.0)
            summary = rt.run(online, offline, duration=4.0, max_prompt=48,
                             max_output=48, drain=False)
            outs.append((summary, rt.finished_signature()))
        (s1, sig1), (s2, sig2) = outs
        assert sig1 == sig2
        assert s1 == s2
        assert s1["mixed_horizon_rounds"] > 0
        assert s1["dispatches_by_kind"]["mixed_horizon"] == \
            s1["mixed_horizon_rounds"]
        assert s1["online_slo_attainment"] == 1.0


class TestServeKnobValidation:
    """--chunk-tokens / --decode-horizon / --max-online-queue reject junk
    with a one-line usage error (exit 2), not a runtime traceback."""

    def test_valid_values_parse(self):
        from repro.launch.serve import build_parser
        ap = build_parser()
        ns = ap.parse_args(["--chunk-tokens", "auto", "--decode-horizon",
                            "4", "--max-online-queue", "3",
                            "--replay-hw", "v5e"])
        assert ns.chunk_tokens == "auto" and ns.decode_horizon == 4
        assert ns.max_online_queue == 3 and ns.replay_hw == "v5e"
        assert ap.parse_args(["--chunk-tokens", "0"]).chunk_tokens == 0
        assert ap.parse_args([]).max_online_queue is None

    @pytest.mark.parametrize("argv", [
        ["--chunk-tokens", "-1"],
        ["--chunk-tokens", "junk"],
        ["--decode-horizon", "-2"],
        ["--decode-horizon", "1.5"],
        ["--max-online-queue", "0"],
        ["--max-online-queue", "none"],
        ["--replay-hw", "h100"],
    ])
    def test_junk_exits_with_usage_error(self, argv):
        from repro.launch.serve import build_parser
        with pytest.raises(SystemExit) as exc:
            build_parser().parse_args(argv)
        assert exc.value.code == 2
