"""Dry-run smoke: one (arch, shape) lowers+compiles per step kind, in a
subprocess with the 512-device flag (the only place it may be set).

Marked slow-ish (~1 min); the full 40-pair x 2-mesh evidence lives in
dryrun_*.json (see EXPERIMENTS.md §Dry-run).
"""
import json
import subprocess
import sys

import pytest

CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
from repro.launch.dryrun import lower_case
res, _, compiled = lower_case("{arch}", "{shape}")
assert compiled is not None
rf = res["roofline"]
assert rf["hlo_flops_per_device"] > 0
assert rf["dominant"] in ("compute", "memory", "collective")
# analytic cross-check: HLO dot flops within 3x of the paper-model flops
ratio = rf["hlo_flops_cluster"] / max(rf["analytic_flops_cluster"], 1)
assert 0.2 < ratio < 5.0, ratio
print(json.dumps({{"ok": True, "dominant": rf["dominant"], "ratio": ratio}}))
"""


@pytest.mark.parametrize("arch,shape", [
    ("tinyllama-1.1b", "decode_32k"),
    ("rwkv6-1.6b", "long_500k"),
])
def test_dryrun_subprocess(arch, shape):
    out = subprocess.run(
        [sys.executable, "-c", CODE.format(arch=arch, shape=shape)],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root",
             # the dry-run forces 512 *host* devices; pin the platform so
             # jax doesn't burn 60s probing a TPU backend first
             "JAX_PLATFORMS": "cpu"})
    assert out.returncode == 0, out.stderr[-2000:]
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    assert payload["ok"]


def test_mesh_shapes():
    """Mesh construction logic (without touching global device state)."""
    from repro.launch.mesh import make_production_mesh  # noqa: F401 import ok
    # shapes/axes are asserted in the dry-run itself; here just check the
    # module contract exists with the right signature
    import inspect
    sig = inspect.signature(make_production_mesh)
    assert "multi_pod" in sig.parameters
