"""Live gateway suite (ISSUE 9): cancellation, deadlines, backpressure,
health-checked drain, and the asyncio streaming front end.

* ``PoolRuntime.submit`` validation — empty prompts, length mismatches,
  duplicate rids fail loudly before touching engine state;
* bounded online admission (``AdmissionRejected`` + ``rejected_online``),
  with offline submits never subject to the online bound;
* ``PoolRuntime.cancel`` at every lifecycle stage — queued, mid-chunked-
  prefill, mid-decode, parked in ``place_queue`` — frees every KV page,
  bills zero recompute, and leaves the runtime steppable; unknown /
  double / after-finish cancels raise ``ValueError``;
* TTFT/total deadlines enforced by the runtime loop under ``VirtualClock``
  (deterministic), billed as SLO violations, never attainment — while
  client cancels leave the SLO denominator entirely;
* the ``evict`` recompute-accounting fix: prefix-cached tokens are a page
  table update, not compute, so they never count as recompute waste;
* interruptible ``WallClock.idle_until`` slices (the gateway's wake path);
* the asyncio ``Gateway`` end to end on a wall clock with ``time.sleep``
  monkeypatched out of idle slices: submit → stream → finish,
  cancel-while-queued, mid-stream cancel, health probe, and a graceful
  drain that ends with zero live pages on every engine.
"""
import asyncio
import threading
import time

import jax
import pytest

from repro.cluster.gateway import Gateway, GatewayClosed, TokenStream
from repro.cluster.runtime import (AdmissionRejected, PoolRuntime,
                                   VirtualClock, WallClock, replay_hw)
from repro.configs import get_config
from repro.core.request import Kind, Phase, Request
from repro.models.model import build_model

SLO_TTFT = 1.0
SLO_TPOT = 0.030


@pytest.fixture(scope="module")
def built():
    cfg = get_config("qwen2.5-7b").reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params, [None]   # last slot: shared kernel donor


def _make_rt(built, *, num_pages=256, clock=None, **kw):
    cfg, model, params, donor = built
    kw.setdefault("policy", "ooco")
    kw.setdefault("n_strict", 1)
    kw.setdefault("n_relaxed", 1)
    kw.setdefault("hw", replay_hw())
    rt = PoolRuntime(cfg, clock=clock or VirtualClock(), backend="ref",
                     num_pages=num_pages, page_size=8, slo_ttft=SLO_TTFT,
                     slo_tpot=SLO_TPOT, model=model,
                     params=params, kernels_from=donor[0], **kw)
    donor[0] = donor[0] or rt.kernel_donor
    return rt


def _submit_online(rt, prompt_len=8, output_len=4, **kw):
    req = Request(Kind.ONLINE, rt.clock.now(), prompt_len, output_len, **kw)
    rt.submit(req, [1] * prompt_len)
    return req


def _step_until(rt, cond, max_steps=200):
    for _ in range(max_steps):
        if cond():
            return True
        rt.step()
    return cond()


def _total_live_pages(rt):
    return sum(rt.live_pages().values())


# ---------------------------------------------------------------------------
# submit validation + backpressure
# ---------------------------------------------------------------------------

class TestSubmitValidation:
    def test_empty_prompt_rejected(self, built):
        rt = _make_rt(built)
        req = Request(Kind.ONLINE, 0.0, 0, 4)
        with pytest.raises(ValueError, match="empty token list"):
            rt.submit(req, [])

    def test_length_mismatch_rejected(self, built):
        rt = _make_rt(built)
        req = Request(Kind.ONLINE, 0.0, 8, 4)
        with pytest.raises(ValueError, match="prompt_len=8 but 5 tokens"):
            rt.submit(req, [1] * 5)

    def test_duplicate_rid_rejected(self, built):
        rt = _make_rt(built)
        req = _submit_online(rt)
        with pytest.raises(ValueError, match="duplicate rid"):
            rt.submit(req, [1] * 8)
        assert len(rt.online_queue) == 1   # first submit intact

    def test_bad_max_online_queue_rejected(self, built):
        with pytest.raises(ValueError, match="max_online_queue"):
            _make_rt(built, max_online_queue=0)


class TestBackpressure:
    def test_online_overflow_raises_and_counts(self, built):
        rt = _make_rt(built, max_online_queue=2)
        a, b = _submit_online(rt), _submit_online(rt)
        with pytest.raises(AdmissionRejected, match="admission queue full"):
            _submit_online(rt)
        assert rt.metrics.rejected_online == 1
        rejected = rt.rejected[0]
        assert rejected.phase is Phase.CANCELLED
        assert rejected.cancel_reason == "rejected"
        # the rejected request left no state behind: not queued, not known
        assert {e[0].rid for e in rt.online_queue} == {a.rid, b.rid}
        assert rejected.rid not in rt.by_rid
        assert rt.summary()["rejected_online"] == 1

    def test_offline_not_bounded_by_online_queue(self, built):
        rt = _make_rt(built, max_online_queue=1)
        _submit_online(rt)
        off = Request(Kind.OFFLINE, 0.0, 8, 4)
        rt.submit(off, [1] * 8)   # must not raise
        assert len(rt.offline_queue) == 1

    def test_queue_drain_reopens_admission(self, built):
        rt = _make_rt(built, max_online_queue=1)
        first = _submit_online(rt)
        with pytest.raises(AdmissionRejected):
            _submit_online(rt)
        assert _step_until(rt, lambda: not rt.online_queue)
        second = _submit_online(rt)   # space again once scheduled
        assert second.rid in rt.by_rid
        assert first.rid in rt.by_rid


# ---------------------------------------------------------------------------
# cancellation at every lifecycle stage
# ---------------------------------------------------------------------------

class TestCancel:
    def test_unknown_rid(self, built):
        rt = _make_rt(built)
        with pytest.raises(ValueError, match="unknown rid"):
            rt.cancel(10**9)

    def test_cancel_while_queued(self, built):
        rt = _make_rt(built)
        req = _submit_online(rt)
        out = rt.cancel(req.rid)
        assert out is req and req.phase is Phase.CANCELLED
        assert req.cancel_reason == "client"
        assert not rt.online_queue and req.rid not in rt.prompts
        assert rt.metrics.cancelled == 1
        assert _total_live_pages(rt) == 0

    def test_double_cancel_and_cancel_after_finish(self, built):
        rt = _make_rt(built)
        req = _submit_online(rt)
        rt.cancel(req.rid)
        with pytest.raises(ValueError, match="already cancelled"):
            rt.cancel(req.rid)
        done = _submit_online(rt)
        assert _step_until(rt, lambda: done.phase is Phase.FINISHED)
        with pytest.raises(ValueError, match="already finished"):
            rt.cancel(done.rid)

    def test_cancel_mid_chunked_prefill(self, built):
        """Cancel between chunk boundaries: the pinned chunk state and its
        partially-filled pages vanish, no recompute is billed (nothing will
        re-run), and the runtime keeps stepping normally."""
        rt = _make_rt(built, chunk_tokens=16)
        slot = rt.relaxed_pool[0]
        req = Request(Kind.ONLINE, 0.0, 48, 4)
        toks = [1] * 48
        rt.submit(req, toks)
        rt.online_queue.pop()              # simulate chunk admission...
        slot.engine.add_request(req, toks)
        slot.prefilling.append((req, toks))
        slot.engine.mixed_step([], req.rid, 16)   # ...land exactly 1 chunk
        assert slot.engine.prefill_progress(req.rid) == 16
        assert _total_live_pages(rt) > 0
        rt.cancel(req.rid)
        assert req.rid not in slot.engine.chunk_state
        assert not slot.prefilling
        assert req.recompute_tokens == 0
        assert _total_live_pages(rt) == 0
        other = _submit_online(rt)   # the pool is still serviceable
        assert _step_until(rt, lambda: other.phase is Phase.FINISHED)

    def test_cancel_mid_decode(self, built):
        rt = _make_rt(built)
        req = _submit_online(rt, output_len=32)
        assert _step_until(rt, lambda: 0 < req.generated < req.output_len)
        rt.cancel(req.rid)
        assert req.phase is Phase.CANCELLED
        assert req.recompute_tokens == 0
        rt.release_retained()   # drop the prefix tree's own page refs
        assert _total_live_pages(rt) == 0
        assert all(req.rid not in s.engine.requests
                   for s in rt.strict_pool + rt.relaxed_pool)

    def test_cancel_parked_migration(self, built):
        """A request parked in ``place_queue`` (its migration destination
        is retrying) cancels cleanly out of the parking lot."""
        rt = _make_rt(built)
        req = Request(Kind.OFFLINE, 0.0, 8, 4)
        rt.submit(req, [1] * 8)
        entry = rt.offline_queue.pop()
        rt.place_queue.append((entry[0], rt.relaxed_pool[0]))
        rt.cancel(req.rid)
        assert not rt.place_queue
        assert req.phase is Phase.CANCELLED
        rt.step()   # no stale placement resurrects the request
        assert req.rid not in {e[0].rid for e in rt.offline_queue}

    def test_cancelled_excluded_from_slo_denominator(self, built):
        rt = _make_rt(built)
        done = _submit_online(rt)
        gone = _submit_online(rt)
        rt.cancel(gone.rid)
        assert _step_until(rt, lambda: done.phase is Phase.FINISHED)
        s = rt.summary()
        assert s["online_requests"] == 1       # client cancels don't count
        assert s["cancelled"] == 1


# ---------------------------------------------------------------------------
# deadlines (deterministic under VirtualClock)
# ---------------------------------------------------------------------------

class TestDeadlines:
    def test_total_deadline_aborts_and_bills_violation(self, built):
        rt = _make_rt(built)
        req = _submit_online(rt, output_len=512, total_deadline=0.05)
        assert _step_until(rt, lambda: req.phase is Phase.CANCELLED)
        assert req.cancel_reason == "deadline"
        assert rt.metrics.deadline_aborts == 1
        rt.release_retained()   # drop the prefix tree's own page refs
        assert _total_live_pages(rt) == 0
        s = rt.summary()
        assert s["deadline_aborts"] == 1
        assert s["online_requests"] == 1       # stays in the denominator...
        assert s["online_slo_attainment"] == 0.0   # ...as a violation

    def test_ttft_deadline_aborts_queued_request(self, built):
        rt = _make_rt(built)
        # park it behind an empty round so the clock moves past the deadline
        req = _submit_online(rt, ttft_deadline=0.01)
        rt.online_queue.clear()            # starved: never scheduled
        rt.clock.advance(0.02)
        rt.step()
        assert req.phase is Phase.CANCELLED
        assert req.cancel_reason == "deadline"

    def test_loose_deadline_finishes_normally(self, built):
        rt = _make_rt(built)
        req = _submit_online(rt, total_deadline=60.0, ttft_deadline=30.0)
        assert _step_until(rt, lambda: req.phase is Phase.FINISHED)
        assert rt.metrics.deadline_aborts == 0
        assert rt.summary()["deadline_aborts"] == 0


# ---------------------------------------------------------------------------
# evict accounting fix (satellite: abort-path double-count sweep)
# ---------------------------------------------------------------------------

class TestEvictAccounting:
    def test_evict_bills_only_uncached_context(self, built):
        rt = _make_rt(built)
        slot = rt.relaxed_pool[0]
        req = Request(Kind.OFFLINE, 0.0, 16, 8)
        rt.submit(req, [1] * 16)
        rt.offline_queue.clear()
        slot.engine.add_request(req, [1] * 16)
        slot.engine.prefill(req.rid)
        req.generated = 4
        req.cached_tokens = 10           # prefix-cache claim: free to redo
        slot.engine.evict(req.rid)
        assert req.recompute_tokens == req.context_len - 10
        slot.engine.release(req.rid)

    def test_evict_never_bills_negative(self, built):
        rt = _make_rt(built)
        slot = rt.relaxed_pool[0]
        req = Request(Kind.OFFLINE, 0.0, 8, 4)
        rt.submit(req, [1] * 8)
        rt.offline_queue.clear()
        slot.engine.add_request(req, [1] * 8)
        slot.engine.prefill(req.rid)
        req.cached_tokens = req.context_len + 5   # clamp, don't go negative
        slot.engine.evict(req.rid)
        assert req.recompute_tokens == 0
        slot.engine.release(req.rid)


# ---------------------------------------------------------------------------
# interruptible wall-clock idle
# ---------------------------------------------------------------------------

class TestWallClockInterrupt:
    def test_idle_until_wakes_on_interrupt(self):
        ev = threading.Event()
        clock = WallClock(interrupt=ev)
        threading.Timer(0.02, ev.set).start()
        t0 = time.perf_counter()
        clock.idle_until(clock.now() + 30.0)   # would block without the event
        assert time.perf_counter() - t0 < 5.0

    def test_idle_until_sleeps_in_slices(self, monkeypatch):
        naps = []
        monkeypatch.setattr(time, "sleep", lambda s: naps.append(s))
        clock = WallClock()
        target = clock.now() + 10 * WallClock.IDLE_SLICE
        deadline = time.perf_counter() + 5.0
        while clock.now() < target and time.perf_counter() < deadline:
            clock.idle_until(target)
        assert naps and max(naps) <= WallClock.IDLE_SLICE + 1e-9


# ---------------------------------------------------------------------------
# the asyncio gateway, end to end (wall clock, sleep-free idle slices)
# ---------------------------------------------------------------------------

@pytest.fixture()
def quiet_sleep(monkeypatch):
    """Make idle slices yield instead of sleeping so the wall-clock suite
    is fast and scheduling-noise-free; correctness must not depend on
    real sleep durations anywhere in the stack."""
    monkeypatch.setattr(WallClock, "IDLE_SLICE", 0.0005)


def _wall_rt(built, **kw):
    return _make_rt(built, clock=WallClock(), hw=None, **kw)


class TestGateway:
    def test_rejects_virtual_clock(self, built):
        with pytest.raises(ValueError, match="WallClock"):
            Gateway(_make_rt(built))

    def test_submit_stream_finish_and_drain(self, built, quiet_sleep):
        async def run():
            rt = _wall_rt(built)
            gw = Gateway(rt)
            await gw.start()
            stream = await gw.submit(list(range(1, 9)), max_new_tokens=6)
            assert isinstance(stream, TokenStream)
            toks = [t async for t in stream]
            assert stream.outcome == "finished"
            assert len(toks) == 6
            assert toks == rt.generated_tokens(stream.rid)
            report = await gw.drain(timeout=30.0)
            assert all(v == 0 for v in report["leaked_pages"].values())
            assert report["summary"]["online_finished"] == 1
            with pytest.raises(GatewayClosed):
                await gw.submit([1, 2, 3])
        asyncio.run(run())

    def test_cancel_while_queued_closes_stream(self, built, quiet_sleep):
        async def run():
            rt = _wall_rt(built)
            gw = Gateway(rt)
            # admit before the runtime thread exists: the request is
            # provably still queued when the cancel lands (deterministic)
            gw._loop = asyncio.get_running_loop()
            gw._accepting = True
            stream = await gw.submit(list(range(1, 9)), max_new_tokens=8)
            assert len(rt.online_queue) == 1
            assert await stream.cancel()
            toks = [t async for t in stream]
            assert toks == [] and stream.outcome == "cancelled"
            assert not rt.online_queue
            await gw.start()
            report = await gw.drain(timeout=30.0)
            assert all(v == 0 for v in report["leaked_pages"].values())
            assert report["summary"]["cancelled"] == 1
        asyncio.run(run())

    def test_cancel_mid_stream_and_health(self, built, quiet_sleep):
        async def run():
            rt = _wall_rt(built)
            gw = Gateway(rt)
            await gw.start()
            health = gw.health()
            assert health["status"] == "ok" and health["accepting"]
            stream = await gw.submit(list(range(1, 9)), max_new_tokens=64)
            async for _ in stream:
                break                      # first token, then walk away
            cancelled = await stream.cancel()
            async for _ in stream:         # drain to the terminal event
                pass
            if cancelled:
                assert stream.outcome == "cancelled"
            else:                          # benign race: already finished
                assert stream.outcome == "finished"
            assert await gw.cancel(stream.rid) is False   # idempotent
            report = await gw.drain(timeout=30.0)
            assert all(v == 0 for v in report["leaked_pages"].values())
            assert not gw.health()["accepting"]
        asyncio.run(run())

    def test_concurrent_streams_partition_and_zero_leak(self, built,
                                                       quiet_sleep):
        async def run():
            rt = _wall_rt(built, max_online_queue=64)
            gw = Gateway(rt)
            await gw.start()

            async def client(i):
                kw = {"max_new_tokens": 4}
                if i % 4 == 1:
                    kw["total_deadline"] = 120.0
                kind = Kind.OFFLINE if i % 4 == 2 else Kind.ONLINE
                stream = await gw.submit([i + 1] * 8, kind=kind, **kw)
                if i % 4 == 3:
                    if await stream.cancel():
                        return "cancelled"
                async for _ in stream:
                    pass
                return stream.outcome

            outcomes = await asyncio.gather(*(client(i) for i in range(12)))
            assert all(o in ("finished", "cancelled") for o in outcomes)
            report = await gw.drain(timeout=60.0)
            s = report["summary"]
            assert all(v == 0 for v in report["leaked_pages"].values())
            n_cancel = outcomes.count("cancelled")
            assert s["cancelled"] == n_cancel
            assert (s["online_finished"] + s["offline_finished"]
                    == 12 - n_cancel)
            assert s["deadline_aborts"] == 0
        asyncio.run(run())

    def test_runtime_crash_surfaces_as_error_outcome(self, built,
                                                     quiet_sleep):
        async def run():
            rt = _wall_rt(built)
            gw = Gateway(rt)
            # park the submit first so the stream exists before the crash
            gw._loop = asyncio.get_running_loop()
            gw._accepting = True
            stream = await gw.submit(list(range(1, 9)), max_new_tokens=4)

            def boom():
                raise RuntimeError("injected scheduler bug")
            rt.step = boom
            await gw.start()
            toks = [t async for t in stream]
            assert toks == [] and stream.outcome == "error"
            assert gw.health()["status"] == "dead"
            assert "injected scheduler bug" in gw.health()["gateway_error"]
            await gw.stop()
        asyncio.run(run())
