"""Fault-tolerance suite (ISSUE 6): deterministic fault injection, engine
failover, request recovery, and graceful degradation.

* ``FaultPlan`` parsing (compact spec, JSON, file) and validation;
* ``PoolRuntime`` constructor validation — clear ``ValueError``s for
  impossible topologies/SLOs/knobs;
* chaos replays are bit-deterministic: same plan + chaos seed → identical
  summaries and token streams;
* **token parity under recovery**: requests recovered from an injected
  engine crash (relaxed or strict) emit exactly the fault-free streams;
* strict-engine crash promotes a relaxed engine (failover);
* KV-migration retry-with-backoff, corruption detection at the
  destination checksum, and recompute fallback on retry exhaustion;
* the watchdog kills injected-stuck dispatches;
* the full-pool recompute-preemption wedge paths (``_fit_batch`` decode
  wedge and the pinned-chunk abort) never drop requests;
* hypothesis properties (skip-safe per tests/conftest.py): injector
  determinism, and no request is ever silently dropped across
  abort/re-admit/shed cycles;
* ``launch.serve``: atomic metrics writes and byte-identical chaos runs.
"""
import json
import os

import jax
import pytest
from hypothesis import given, settings, strategies as st

from repro.cluster.faults import FaultEvent, FaultInjector, FaultPlan
from repro.cluster.runtime import PoolRuntime, VirtualClock, replay_hw
from repro.configs import get_config
from repro.core import scheduling as sch
from repro.core.request import Kind, Phase, Request
from repro.data import traces as tr
from repro.engine.engine import EngineCrashedError, ServingEngine
from repro.engine.kv_cache import (TransferIntegrityError, transfer_checksum,
                                   verify_transfer)
from repro.models.model import build_model

SLO_TTFT = 1.0
SLO_TPOT = 0.030


# ---------------------------------------------------------------------------
# plan parsing + injector (no engines needed)
# ---------------------------------------------------------------------------

class TestFaultPlanParsing:
    def test_compact_spec(self):
        p = FaultPlan.parse("crash:relaxed1@3.0,stuck:relaxed0@2.0,"
                            "page_leak:strict0@1.5:pages=64:duration=2.0,"
                            "migration_flaky:p=0.25")
        kinds = [e.kind for e in p.events]
        assert kinds == ["crash", "stuck", "page_leak", "migration_flaky"]
        assert p.events[0].engine == "relaxed1" and p.events[0].at == 3.0
        assert p.events[2].pages == 64 and p.events[2].duration == 2.0
        assert p.events[3].p == 0.25

    def test_json_and_file(self, tmp_path):
        blob = json.dumps([{"kind": "crash", "engine": "relaxed0", "at": 1.0},
                           {"kind": "migration_fail", "count": 2}])
        p = FaultPlan.parse(blob)
        assert [e.kind for e in p.events] == ["crash", "migration_fail"]
        f = tmp_path / "plan.json"
        f.write_text(blob)
        assert FaultPlan.parse(str(f)).events == p.events

    def test_passthrough_and_empty(self):
        assert FaultPlan.parse(None) is None
        assert FaultPlan.parse("") is None
        p = FaultPlan([FaultEvent("migration_fail")])
        assert FaultPlan.parse(p) is p
        assert FaultPlan.parse([FaultEvent("migration_fail")]).events

    @pytest.mark.parametrize("bad", [
        "explode:relaxed0@1.0",            # unknown kind
        "crash@1.0",                       # crash needs an engine
        "page_leak:relaxed0:pages=0",      # pages must be > 0
        "migration_flaky:p=1.5",           # p out of range
        "crash:relaxed0@-1.0",             # negative time
    ])
    def test_invalid_specs_raise(self, bad):
        with pytest.raises(ValueError):
            FaultPlan.parse(bad)

    def test_injector_one_shot_and_counters(self):
        inj = FaultInjector(FaultPlan.parse("crash:relaxed0@2.0"), seed=0)
        assert inj.crashes_due(1.0) == []
        assert inj.crashes_due(2.5) == ["relaxed0"]
        assert inj.crashes_due(3.0) == []          # one-shot
        assert inj.faults_injected == 1

    def test_planned_failures_drain_before_flaky(self):
        inj = FaultInjector(
            FaultPlan.parse("migration_fail:count=2,migration_corrupt"), 3)
        assert [inj.transfer_outcome(0.0) for _ in range(3)] \
            == ["fail", "fail", "corrupt"]
        assert inj.transfer_outcome(0.0) == "ok"   # no flaky event armed


class TestAdmissionDecision:
    def test_admits_when_idle(self):
        assert sch.admission_decision(queued_online=0, strict_pressure=0.2,
                                      offline_backlog=50) == "admit"

    def test_defers_on_deep_online_queue(self):
        assert sch.admission_decision(queued_online=8, strict_pressure=0.0,
                                      offline_backlog=0) == "defer"

    def test_pressure_only_matters_with_online_waiting(self):
        assert sch.admission_decision(queued_online=0, strict_pressure=1.0,
                                      offline_backlog=10) == "admit"
        assert sch.admission_decision(queued_online=1, strict_pressure=1.0,
                                      offline_backlog=10) == "defer"

    def test_sheds_only_with_bounded_backlog(self):
        kw = dict(queued_online=9, strict_pressure=1.0, offline_backlog=100)
        assert sch.admission_decision(**kw) == "defer"          # unbounded
        assert sch.admission_decision(**kw, max_backlog=10) == "shed"
        assert sch.admission_decision(**kw, max_backlog=200) == "defer"

    def test_page_exhaustion_defers(self):
        assert sch.admission_decision(queued_online=0, strict_pressure=0.0,
                                      offline_backlog=5,
                                      free_page_frac=0.0) == "defer"


class TestTransferIntegrity:
    def test_checksum_round_trip_and_corruption(self):
        import numpy as np
        k = np.arange(24, dtype=np.float32).reshape(2, 3, 2, 2)
        v = k + 0.5
        c = transfer_checksum(k, v)
        verify_transfer(k, v, c)                    # exact payload passes
        bad = k.copy()
        bad.flat[0] += 1.0
        with pytest.raises(TransferIntegrityError):
            verify_transfer(bad, v, c)


# ---------------------------------------------------------------------------
# runtime fixtures (real engines, module-scoped model)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def built():
    cfg = get_config("qwen2.5-7b").reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params, [None]   # last slot: shared kernel donor


def _make_rt(built, *, num_pages=256, **kw):
    cfg, model, params, donor = built
    kw.setdefault("policy", "ooco")
    kw.setdefault("n_strict", 1)
    kw.setdefault("n_relaxed", 2)
    rt = PoolRuntime(cfg, clock=VirtualClock(), backend="ref",
                     num_pages=num_pages, page_size=8, slo_ttft=SLO_TTFT,
                     slo_tpot=SLO_TPOT, hw=replay_hw(), model=model,
                     params=params, kernels_from=donor[0], **kw)
    donor[0] = donor[0] or rt.kernel_donor
    return rt


def _replay(built, fault_plan=None, *, duration=6.0, n_offline=40, **kw):
    """Drained deterministic replay: every request finishes in the clean
    run, so ``finished_signature`` equality against a chaos run asserts
    both recovery completeness AND per-request token parity."""
    rt = _make_rt(built, fault_plan=fault_plan, chaos_seed=7, **kw)
    online = tr.online_trace("ooc", duration=duration, mean_qps=1.2, seed=0)
    offline = tr.with_uniform_qps(tr.offline_requests(n_offline, seed=1), 20.0)
    summary = rt.run(online, offline, duration=duration, max_prompt=48,
                     max_output=12, drain=True)
    return summary, rt


CHAOS_PLAN = ("crash:relaxed1@2.0,stuck:relaxed0@1.0,"
              "page_leak:relaxed0@0.5:pages=16:duration=1.5,"
              "migration_flaky:p=0.3")


@pytest.fixture(scope="module")
def clean_run(built):
    return _replay(built)


@pytest.fixture(scope="module")
def chaos_runs(built):
    return _replay(built, CHAOS_PLAN), _replay(built, CHAOS_PLAN)


class TestChaosDeterminism:
    def test_bit_identical_summaries_and_tokens(self, chaos_runs):
        (m1, rt1), (m2, rt2) = chaos_runs
        assert m1 == m2
        assert rt1.finished_signature() == rt2.finished_signature()
        assert rt1.finished

    def test_faults_actually_fired(self, chaos_runs):
        (m, _), _ = chaos_runs
        assert m["engine_crashes"] == 1
        assert m["watchdog_aborts"] == 1
        assert m["faults_injected"] >= 3
        assert m["recoveries"] >= 1
        assert m["n_relaxed"] == 1          # one relaxed engine is gone


class TestRecoveryTokenParity:
    def test_relaxed_crash_token_parity(self, clean_run, chaos_runs):
        """Every request recovered from the crashed relaxed engine emits
        exactly the fault-free stream (drain mode: both runs finish the
        whole trace, so signature equality is full per-request parity)."""
        _, rt_clean = clean_run
        (m, rt_chaos), _ = chaos_runs
        assert rt_chaos.finished_signature() == rt_clean.finished_signature()
        assert m["recompute_tokens"] > 0    # recovery really recomputed

    def test_online_slo_survives_relaxed_crash(self, chaos_runs):
        (m, _), _ = chaos_runs
        assert m["online_slo_attainment"] == 1.0
        assert m["online_finished"] == m["online_requests"]

    def test_strict_crash_promotes_and_preserves_parity(self, built,
                                                        clean_run):
        _, rt_clean = clean_run
        m, rt = _replay(built, "crash:strict0@2.0")
        assert m["engine_crashes"] == 1
        assert m["promotions"] == 1
        assert m["n_strict"] == 1           # promoted replacement in place
        assert m["n_relaxed"] == 1
        assert rt.finished_signature() == rt_clean.finished_signature()


class TestMigrationRetry:
    def test_planned_failures_retry_then_succeed(self, built, clean_run):
        _, rt_clean = clean_run
        m, rt = _replay(built, "migration_fail:count=2")
        assert m["migration_retries"] >= 2
        assert m["migration_recomputes"] == 0   # budget (3) never exhausted
        assert m["migrations"] > 0
        assert rt.finished_signature() == rt_clean.finished_signature()

    def test_corruption_detected_and_retried(self, built, clean_run):
        _, rt_clean = clean_run
        m, rt = _replay(built, "migration_corrupt:count=1")
        assert m["migration_retries"] >= 1
        assert rt.finished_signature() == rt_clean.finished_signature()

    def test_retry_exhaustion_falls_back_to_recompute(self, built,
                                                      clean_run):
        _, rt_clean = clean_run
        m, rt = _replay(built, "migration_fail:count=3")   # = attempt budget
        assert m["migration_recomputes"] >= 1
        assert m["migration_retries"] >= 3
        # the recomputed request is not lost — full drain still matches
        assert rt.finished_signature() == rt_clean.finished_signature()


class TestConstructorValidation:
    @pytest.mark.parametrize("kw,match", [
        (dict(policy="bogus"), "unknown policy"),
        (dict(n_strict=0), "strict"),
        (dict(n_relaxed=0), "relaxed"),
        (dict(slo_ttft=-1.0), "SLO"),
        (dict(slo_tpot=0.0), "SLO"),
        (dict(num_pages=1), "num_pages"),
        (dict(page_size=0), "page_size"),
        (dict(decode_horizon=-3), "decode_horizon"),
        (dict(decode_horizon="fast"), "decode_horizon"),
        (dict(chunk_tokens=-5), "chunk_tokens"),
        (dict(max_horizon=0), "max_horizon"),
        (dict(max_transfer_attempts=0), "max_transfer_attempts"),
        (dict(max_offline_backlog=-1), "max_offline_backlog"),
    ])
    def test_bad_args_raise_clear_valueerrors(self, built, kw, match):
        cfg = built[0]
        with pytest.raises(ValueError, match=match):
            PoolRuntime(cfg, **kw)          # raises before engines build


class TestEngineCrash:
    def test_crashed_engine_refuses_dispatch(self, built):
        cfg, model, params, donor = built
        eng = ServingEngine(model, params, num_pages=32, page_size=8,
                            backend="ref", kernels_from=donor[0])
        donor[0] = donor[0] or eng
        req = Request(Kind.OFFLINE, 0.0, 8, 4)
        eng.add_request(req, [1] * 8)
        eng.prefill(req.rid)
        eng.crash()
        assert not eng.alive
        assert not eng.requests and not eng.cache.tables
        with pytest.raises(EngineCrashedError):
            eng.decode_step([req.rid])
        with pytest.raises(EngineCrashedError):
            eng.add_request(Request(Kind.OFFLINE, 0.0, 8, 4), [1] * 8)


# ---------------------------------------------------------------------------
# full-pool recompute-preemption wedge paths (satellite c)
# ---------------------------------------------------------------------------

class TestFullPoolWedge:
    def _resident(self, rt, slot, prompt_len=64, output_len=300):
        req = Request(Kind.OFFLINE, 0.0, prompt_len, output_len)
        toks = [1] * prompt_len
        rt.submit(req, toks)
        rt.offline_queue.clear()             # place it by hand
        slot.engine.add_request(req, toks)
        slot.engine.prefill(req.rid)
        slot.offline.append(req)
        return req

    def test_fit_batch_wedge_evicts_to_unblock_head(self, built):
        """A full pool where no decode row fits must evict other offline
        residents to unblock the head request — and the victims land back
        in the offline queue (recompute later), never dropped."""
        rt = _make_rt(built, num_pages=64)
        slot = rt.relaxed_pool[0]
        reqs = [self._resident(rt, slot) for _ in range(7)]
        cache = slot.engine.cache
        free = cache.allocator.free_pages
        for r in reqs:   # claim growth exactly one page beyond free space
            r.generated = (free + 1) * cache.page_size
        batch = rt._fit_batch(slot, list(reqs))
        assert batch == [reqs[0]]            # head unblocked via eviction
        assert rt.metrics.evictions > 0
        requeued = {e[0].rid for e in rt.offline_queue}
        survivors = {r.rid for r in slot.offline}
        # every resident is either still on the engine or requeued
        assert requeued | survivors == {r.rid for r in reqs}
        assert all(r.recompute_tokens > 0
                   for r in reqs if r.rid in requeued)

    def test_pinned_chunk_abort_requeues_request(self, built):
        """A pinned chunk prefill on a wedged pool (nothing decodable, no
        chunk admissible, no evictable residents) is aborted back to the
        queue instead of wedging the engine forever."""
        rt = _make_rt(built, num_pages=64)
        slot = rt.relaxed_pool[0]
        req = Request(Kind.OFFLINE, 0.0, 48, 8)
        toks = [1] * 48
        rt.submit(req, toks)
        rt.offline_queue.clear()
        slot.engine.add_request(req, toks)
        entry = (req, toks)
        slot.prefilling.append(entry)
        hog = slot.engine.cache.allocator.alloc(
            slot.engine.cache.allocator.free_pages)   # exhaust the pool
        cost = rt._decode_slot(slot, 0.0, relaxed=True, prefill=entry)
        assert cost == 0.0
        assert not slot.prefilling                    # unpinned
        assert req.rid not in slot.engine.requests    # engine state cleaned
        assert any(e[0] is req for e in rt.offline_queue)   # requeued
        slot.engine.cache.allocator.free(hog)

    def test_contended_replay_drains_without_drops(self, built):
        """End-to-end: a pool far too small for the backlog forces the
        eviction/recompute machinery constantly (and regression-guards the
        decode-batch page reservation against the fused prefill chunk —
        this config OutOfPagesError'd before the reservation); everything
        still finishes."""
        rt = _make_rt(built, num_pages=40)
        online = tr.online_trace("ooc", duration=5.0, mean_qps=3.0, seed=0)
        offline = tr.with_uniform_qps(tr.offline_requests(16, seed=1), 20.0)
        m = rt.run(online, offline, duration=5.0, max_prompt=48,
                   max_output=24, drain=True)
        assert m["online_finished"] == m["online_requests"]
        assert m["offline_finished"] == m["offline_requests"]
        assert m["evictions"] > 0 and m["recompute_tokens"] > 0


# ---------------------------------------------------------------------------
# hypothesis properties (skip-safe when hypothesis is absent)
# ---------------------------------------------------------------------------

class TestProperties:
    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 24))
    @settings(max_examples=30, deadline=None)
    def test_injector_outcome_sequence_deterministic(self, seed, n):
        plan = "migration_flaky:p=0.5,migration_fail:count=2"
        a = FaultInjector(FaultPlan.parse(plan), seed)
        b = FaultInjector(FaultPlan.parse(plan), seed)
        assert [a.transfer_outcome(0.0) for _ in range(n)] \
            == [b.transfer_outcome(0.0) for _ in range(n)]
        assert [a.backoff_seconds(i, 0.05) for i in range(1, 4)] \
            == [b.backoff_seconds(i, 0.05) for i in range(1, 4)]

    @given(data=st.data())
    @settings(max_examples=25, deadline=None)
    def test_no_request_silently_dropped(self, built, data):
        """Across any interleaving of abort/re-admit cycles, shedding, and
        client cancellation, every submitted request is in exactly one
        place: a queue, the (surfaced) shed list, or the cancelled list —
        never lost, never duplicated."""
        rt = _prop_rt(built)
        rt.online_queue.clear()
        rt.offline_queue.clear()
        rt.shed.clear()
        rt.cancelled.clear()
        rt.prompts.clear()
        rt.all_requests.clear()
        rt.metrics.shed_requests = 0
        rt.metrics.cancelled = 0
        rt.max_offline_backlog = data.draw(
            st.one_of(st.none(), st.integers(0, 4)))
        reqs = []
        for i in range(data.draw(st.integers(1, 10))):
            kind = data.draw(st.sampled_from([Kind.ONLINE, Kind.OFFLINE]))
            r = Request(kind, float(i), 8, 4)
            rt.submit(r, [0] * 8)
            reqs.append(r)
        for _ in range(data.draw(st.integers(0, 15))):
            action = data.draw(st.sampled_from(["shed", "cancel", "readmit"]))
            if action == "shed" and rt.max_offline_backlog is not None:
                rt._shed_offline()
                continue
            pool = rt.offline_queue if rt.offline_queue else rt.online_queue
            if not pool:
                continue
            if action == "cancel":
                entry = pool[data.draw(st.integers(0, len(pool) - 1))]
                rt.cancel(entry[0].rid)
                continue
            entry = pool.pop(data.draw(st.integers(0, len(pool) - 1)))
            req = entry[0]
            # simulate arbitrary partial progress lost with the abort
            req.prefill_tokens_done = data.draw(st.integers(0, req.prompt_len))
            req.generated = data.draw(st.integers(0, req.output_len - 1))
            rt._readmit(req)
        queued = ([e[0].rid for e in rt.online_queue]
                  + [e[0].rid for e in rt.offline_queue])
        shed = [r.rid for r in rt.shed]
        cancelled = [r.rid for r in rt.cancelled]
        assert sorted(queued + shed + cancelled) \
            == sorted(r.rid for r in reqs)
        assert rt.metrics.shed_requests == len(shed)
        assert rt.metrics.cancelled == len(cancelled)
        assert all(rt.by_rid[rid].phase is Phase.CANCELLED
                   for rid in cancelled)


_PROP_RT = []


def _prop_rt(built):
    """One dedicated runtime for the queue-accounting property (module
    model, fresh engines once — examples reset the queue state)."""
    if not _PROP_RT:
        _PROP_RT.append(_make_rt(built, num_pages=32, n_relaxed=1))
    return _PROP_RT[0]


# ---------------------------------------------------------------------------
# launch.serve: atomic writes + chaos flags (satellites a, d, e)
# ---------------------------------------------------------------------------

class TestServe:
    def test_atomic_write_no_partial_on_failure(self, tmp_path, monkeypatch):
        from repro.launch import serve
        path = tmp_path / "m.json"
        serve.write_json_atomic(str(path), "first\n")
        assert path.read_text() == "first\n"

        def boom(src, dst):
            raise RuntimeError("crash mid-write")
        monkeypatch.setattr(serve.os, "replace", boom)
        with pytest.raises(RuntimeError):
            serve.write_json_atomic(str(path), "second\n")
        monkeypatch.undo()
        assert path.read_text() == "first\n"       # old file intact
        assert os.listdir(tmp_path) == ["m.json"]  # temp file cleaned up
        serve.write_json_atomic(str(path), "third\n")
        assert path.read_text() == "third\n"

    def test_chaos_serve_byte_deterministic(self, tmp_path, capsys):
        from repro.launch.serve import main
        argv = ["--virtual-clock", "--policy", "ooco", "--strict", "1",
                "--relaxed", "2", "--duration", "4", "--online-qps", "1.0",
                "--offline-qps", "4.0", "--num-pages", "256",
                "--slo-ttft", "1.0", "--slo-tpot", "0.030",
                "--fault-plan", "crash:relaxed1@2.0", "--chaos-seed", "7"]
        blobs = []
        for i in (0, 1):
            mp = tmp_path / f"m{i}.json"
            tp = tmp_path / f"t{i}.json"
            s = main(argv + ["--metrics-json", str(mp),
                             "--tokens-json", str(tp)])
            assert s["faults_injected"] == 1 and s["engine_crashes"] == 1
            blobs.append((mp.read_bytes(), tp.read_bytes()))
        capsys.readouterr()
        assert blobs[0] == blobs[1]
