"""Cross-request KV reuse (ISSUE 7): refcounted copy-on-write pages, the
radix prefix cache, and the cache-aware roofline.

* ``BlockAllocator`` refcount semantics: double-free / unknown-page /
  reserved-page frees raise ``DoubleFreeError``; shared pages recycle only
  when the LAST owner releases them;
* ``RadixPrefixCache``: block-aligned matching capped below the prompt
  length, deterministic LRU eviction preferring unshared leaves,
  ``touch=False`` planning peeks that do not perturb eviction order;
* engine-level bit parity: a warm prefill that claims cached prefix pages
  produces token streams identical to a cold prefill, request by request;
* cluster: ``select_eviction_victims`` prefers unshared pages and never
  counts shared ones as freed; the cache-aware roofline charges a
  page-table update instead of prefill FLOPs for cached tokens;
* runtime replay: shared-prefix trace with cache on vs off — identical
  ``finished_signature()``, hit counters live in ``summary()``;
* property tests (hypothesis, skip-safe per tests/conftest.py): page-count
  conservation and no-free-while-referenced under arbitrary
  insert/match/evict/abort churn, and cache-on vs cache-off token parity.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core import scheduling as sch
from repro.core.hardware import TPU_V5E
from repro.core.perf_model import PerfModel
from repro.core.request import Kind, Phase, Request
from repro.data import traces as tr
from repro.engine.engine import ServingEngine
from repro.engine.kv_cache import (BlockAllocator, DoubleFreeError,
                                   OutOfPagesError, PagedKVCache,
                                   RadixPrefixCache)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2.5-7b").reduced()
    from repro.models.model import build_model
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _ref_generate(model, params, prompt, n_new):
    import jax.numpy as jnp
    toks = list(prompt)
    logits, cache = model.prefill(
        params, {"tokens": jnp.asarray([prompt], jnp.int32)},
        cache_len=len(prompt) + n_new)
    toks.append(int(jnp.argmax(logits, -1)[0]))
    for _ in range(n_new - 1):
        logits, cache = model.decode_step(
            params, jnp.asarray([toks[-1]], jnp.int32), cache)
        toks.append(int(jnp.argmax(logits, -1)[0]))
    return toks


# ---------------------------------------------------------------------------
# BlockAllocator: refcounts + DoubleFreeError (the free() hardening satellite)
# ---------------------------------------------------------------------------
class TestAllocatorRefcounts:
    def test_double_free_raises(self):
        a = BlockAllocator(8, reserved=1)
        pages = a.alloc(2)
        a.free(pages)
        with pytest.raises(DoubleFreeError):
            a.free(pages)

    def test_unknown_and_reserved_pages_raise(self):
        a = BlockAllocator(8, reserved=1)
        with pytest.raises(DoubleFreeError):
            a.free([99])               # out of range
        with pytest.raises(DoubleFreeError):
            a.free([-3])               # out of range, negative
        with pytest.raises(DoubleFreeError):
            a.free([0])                # the reserved trash page
        with pytest.raises(DoubleFreeError):
            a.free([5])                # in range but never allocated

    def test_partial_failure_does_not_corrupt_free_list(self):
        a = BlockAllocator(8, reserved=1)
        pages = a.alloc(2)
        with pytest.raises(DoubleFreeError):
            a.free([pages[0], 99])     # first decrefs, second raises
        assert a.refcount(pages[0]) == 0
        assert a.refcount(pages[1]) == 1
        a.free([pages[1]])
        assert a.free_pages == 7

    def test_shared_page_survives_first_free(self):
        a = BlockAllocator(8, reserved=1)
        [p] = a.alloc(1)
        a.incref([p])
        assert a.refcount(p) == 2
        a.free([p])
        assert a.refcount(p) == 1      # sibling still owns it
        assert p not in a._free
        a.free([p])
        assert a.refcount(p) == 0 and p in a._free

    def test_incref_on_non_live_page_raises(self):
        a = BlockAllocator(8, reserved=1)
        with pytest.raises(DoubleFreeError):
            a.incref([3])              # free page: nothing to share
        [p] = a.alloc(1)
        a.free([p])
        with pytest.raises(DoubleFreeError):
            a.incref([p])              # released page: stale reference

    @given(ops=st.lists(
        st.tuples(st.sampled_from(["alloc", "incref", "free"]),
                  st.integers(0, 5)), max_size=60))
    @settings(max_examples=50, deadline=None)
    def test_conservation_and_no_free_while_referenced(self, ops):
        """Against a pure-python owner model: free_pages + live == capacity
        after every op, refcounts match exactly, and no page sits in the
        free list while an owner still holds it."""
        a = BlockAllocator(32, reserved=1)
        owners: list[list[int]] = []   # one entry per outstanding reference
        for op, n in ops:
            if op == "alloc":
                try:
                    owners.append(a.alloc(n))
                except OutOfPagesError:
                    assert a.free_pages < n
            elif op == "incref" and owners:
                src = owners[n % len(owners)]
                a.incref(src)
                owners.append(list(src))
            elif op == "free" and owners:
                a.free(owners.pop(n % len(owners)))
            refs = {}
            for h in owners:
                for p in h:
                    refs[p] = refs.get(p, 0) + 1
            assert a.free_pages + a.live_pages == 31
            assert a.live_pages == len(refs)
            for p, c in refs.items():
                assert a.refcount(p) == c
                assert p not in a._free   # never freed while referenced


# ---------------------------------------------------------------------------
# RadixPrefixCache unit behaviour
# ---------------------------------------------------------------------------
def _seed_tree(alloc, tree, tokens):
    """Prefill ``tokens`` the way an engine would: alloc pages, insert the
    full ones, release the request's own reference. Returns the table."""
    table = alloc.alloc(-(-len(tokens) // tree.page_size))
    tree.insert(tokens, table)
    alloc.free(table)
    return table


class TestRadixPrefixCache:
    def test_match_is_block_aligned_and_capped(self):
        a = BlockAllocator(16, reserved=1)
        t = RadixPrefixCache(a, page_size=4)
        toks = list(range(12))
        _seed_tree(a, t, toks)
        pages, matched = t.match(toks)             # no cap: all 3 pages
        assert matched == 12 and len(pages) == 3
        pages, matched = t.match(toks, limit=11)   # engine cap: < prompt
        assert matched == 8 and len(pages) == 2    # page-aligned below 11
        pages, matched = t.match(toks[:6] + [99] * 6)
        assert matched == 4                        # diverges in page 2
        assert t.match([7, 7, 7, 7]) == ([], 0)    # cold miss

    def test_existing_nodes_win_on_reinsert(self):
        a = BlockAllocator(16, reserved=1)
        t = RadixPrefixCache(a, page_size=4)
        toks = list(range(8))
        _seed_tree(a, t, toks)
        before = t.resident_pages
        tbl2 = a.alloc(2)
        adopted = t.insert(toks, tbl2)             # duplicate prefill lands
        assert adopted == 0                        # first copy wins
        assert t.resident_pages == before
        a.free(tbl2)                               # private copy released
        assert a.free_pages + a.live_pages == 15

    def test_evict_lru_prefers_unshared(self):
        a = BlockAllocator(32, reserved=1)
        t = RadixPrefixCache(a, page_size=4)
        cold = list(range(100, 104))
        _seed_tree(a, t, cold)                     # oldest, unshared
        hot = list(range(200, 204))
        _seed_tree(a, t, hot)
        hot_pages, _ = t.match(hot)                # refresh + share
        a.incref(hot_pages)                        # a request claims it
        freed = t.evict(1)
        assert freed == 1
        assert t.match(cold, touch=False) == ([], 0)   # LRU unshared gone
        assert t.match(hot, touch=False)[1] == 4       # shared one kept
        # evicting past the unshared supply drops shared leaves (decref
        # only) without counting them as freed
        assert t.evict(1) == 0
        assert a.refcount(hot_pages[0]) == 1       # request still owns it

    def test_planning_peek_does_not_perturb_lru(self):
        a = BlockAllocator(32, reserved=1)
        t = RadixPrefixCache(a, page_size=4)
        first = list(range(4))
        second = list(range(10, 14))
        _seed_tree(a, t, first)
        _seed_tree(a, t, second)
        t.match(first, touch=False)                # gating peek: no refresh
        t.evict(1)
        assert t.match(first, touch=False) == ([], 0)  # still the LRU victim
        assert t.match(second, touch=False)[1] == 4

    def test_clear_drops_tree_without_touching_allocator(self):
        a = BlockAllocator(16, reserved=1)
        t = RadixPrefixCache(a, page_size=4)
        _seed_tree(a, t, list(range(8)))
        free_before = a.free_pages
        t.clear()                                  # crash path
        assert t.resident_pages == 0
        assert a.free_pages == free_before         # allocator untouched

    @given(seq=st.lists(
        st.tuples(st.sampled_from(["prefill", "claim", "release", "evict"]),
                  st.integers(0, 7)), max_size=40))
    @settings(max_examples=40, deadline=None)
    def test_conservation_under_churn(self, seq):
        """Arbitrary insert/match/evict/abort sequences: page counts are
        conserved, every tree page stays live, and no page held by a
        request's table is ever recycled out from under it."""
        a = BlockAllocator(24, reserved=1)
        t = RadixPrefixCache(a, page_size=4)
        prompts = [[b, b + 1, b + 2, b + 3, b + 4]
                   for b in range(0, 80, 10)]      # 1 full + 1 partial page
        claims: list[list[int]] = []
        for op, n in seq:
            if op == "prefill":
                toks = prompts[n % len(prompts)]
                try:
                    table = a.alloc(2)
                except OutOfPagesError:
                    continue
                t.insert(toks, table)
                a.free(table)                      # request aborts/finishes
            elif op == "claim":
                pages, m = t.match(prompts[n % len(prompts)])
                if m:
                    a.incref(pages)
                    claims.append(pages)
            elif op == "release" and claims:
                a.free(claims.pop(n % len(claims)))
            elif op == "evict":
                t.evict(n)
            assert a.free_pages + a.live_pages == 23
            held = {p for c in claims for p in c}
            for p in held:
                assert a.refcount(p) >= 1
                assert p not in a._free
            # every resident tree page is live
            stack = list(t.root.children.values())
            while stack:
                node = stack.pop()
                assert a.refcount(node.page) >= 1
                stack.extend(node.children.values())
        for c in claims:
            a.free(c)
        t.evict(a.num_pages)
        assert a.free_pages == 23                  # everything drains


# ---------------------------------------------------------------------------
# PagedKVCache: adopt / available_pages / pressure eviction / shared_tokens
# ---------------------------------------------------------------------------
class TestPagedKVCacheSharing:
    def _cache(self, cfg, pages=16):
        return PagedKVCache(cfg, pages, page_size=4, enable_prefix_cache=True)

    def test_adopt_increfs_and_seeds_table(self, setup):
        cfg, _, _ = setup
        c = self._cache(cfg)
        toks = list(range(9))
        c.ensure(1, 9)
        c.prefix.insert(toks, c.tables[1])
        pages, matched = c.prefix.match(toks, limit=8)
        c.adopt(2, pages, matched)
        assert c.tables[2] == c.tables[1][:2] and c.lengths[2] == 8
        assert all(c.allocator.refcount(p) == 3 for p in pages)
        assert c.shared_tokens(1) == 8 and c.shared_tokens(2) == 8
        with pytest.raises(AssertionError):
            c.adopt(2, pages, matched)             # already holds pages

    def test_available_pages_counts_reclaimable_and_ensure_evicts(self, setup):
        cfg, _, _ = setup
        c = self._cache(cfg, pages=9)              # 8 usable
        c.ensure(1, 32)                            # all 8 pages
        c.prefix.insert(list(range(32)), c.tables[1])
        c.free(1)                                  # tree holds all 8 now
        assert c.allocator.free_pages == 0
        assert c.available_pages == 8              # all reclaimable
        assert c.can_fit(12)
        c.ensure(2, 12)                            # forces tree eviction
        assert len(c.tables[2]) == 3
        assert c.prefix.evictions >= 3

    def test_free_is_a_decref_not_a_release(self, setup):
        cfg, _, _ = setup
        c = self._cache(cfg)
        toks = list(range(8))
        c.ensure(1, 8)
        c.prefix.insert(toks, c.tables[1])
        free_before = c.allocator.free_pages
        c.free(1)                                  # request done
        assert c.allocator.free_pages == free_before   # tree keeps both
        assert c.prefix.match(toks, touch=False)[1] == 8


# ---------------------------------------------------------------------------
# Engine: warm prefill bit-parity with cold prefill (the correctness bar)
# ---------------------------------------------------------------------------
class TestEngineWarmColdParity:
    def test_claimed_prefix_tokens_bit_identical(self, setup):
        """The tentpole invariant: greedy token streams with the cache on
        are bit-identical to a cold prefill, request by request."""
        cfg, model, params = setup
        rng = np.random.RandomState(11)
        shared = list(rng.randint(0, cfg.vocab_size, 16))
        pa = shared + list(rng.randint(0, cfg.vocab_size, 8))
        pb = shared + list(rng.randint(0, cfg.vocab_size, 8))
        ref_a = _ref_generate(model, params, pa, 6)
        ref_b = _ref_generate(model, params, pb, 6)
        eng = ServingEngine(model, params, num_pages=64, page_size=8,
                            prefix_cache=True)
        ra = Request(Kind.OFFLINE, 0.0, len(pa), 6)
        eng.add_request(ra, pa)
        while ra.generated == 0:
            eng.mixed_step([], ra.rid, 8)
        while not ra.done:
            eng.decode_step([ra.rid])
        assert eng.token_buf[ra.rid] == ref_a      # cold path unchanged
        assert eng.cache.prefix.resident_pages == 3   # 24-token prompt
        rb = Request(Kind.OFFLINE, 0.0, len(pb), 6)
        eng.add_request(rb, pb)
        assert eng.claim_prefix(rb.rid) == 16      # 2 shared pages
        assert rb.cached_tokens == 16
        assert rb.prefill_tokens_done == 16        # resumes at the boundary
        assert eng.claim_prefix(rb.rid) == 0       # idempotent: in flight
        while rb.generated == 0:
            eng.mixed_step([], rb.rid, 8)          # only the 8-token suffix
        while not rb.done:
            eng.decode_step([rb.rid])
        assert eng.token_buf[rb.rid] == ref_b      # bit-identical warm path
        assert eng.stats.prefix_hits == 1
        assert eng.stats.cached_tokens == 16
        assert eng.stats.shared_pages == 2

    def test_legacy_prefill_refuses_warm_started_request(self, setup):
        """The whole-table prefill path would rewrite shared pages; it must
        refuse a request that already claimed cached pages."""
        cfg, model, params = setup
        rng = np.random.RandomState(12)
        prompt = list(rng.randint(0, cfg.vocab_size, 17))
        eng = ServingEngine(model, params, num_pages=64, page_size=8,
                            prefix_cache=True)
        ra = Request(Kind.OFFLINE, 0.0, len(prompt), 2)
        eng.add_request(ra, prompt)
        while ra.generated == 0:
            eng.mixed_step([], ra.rid, 8)
        rb = Request(Kind.OFFLINE, 0.0, len(prompt), 2)
        eng.add_request(rb, prompt)
        assert eng.claim_prefix(rb.rid) == 16
        with pytest.raises(AssertionError):
            eng.prefill(rb.rid)

    def test_abort_after_claim_charges_only_computed_tokens(self, setup):
        """Recompute accounting: cached tokens were never computed here, so
        aborting a warm prefill wastes only what it actually ran."""
        cfg, model, params = setup
        rng = np.random.RandomState(13)
        prompt = list(rng.randint(0, cfg.vocab_size, 28))
        eng = ServingEngine(model, params, num_pages=64, page_size=8,
                            prefix_cache=True)
        ra = Request(Kind.OFFLINE, 0.0, len(prompt), 2)
        eng.add_request(ra, prompt)
        while ra.generated == 0:
            eng.mixed_step([], ra.rid, 8)
        rb = Request(Kind.OFFLINE, 0.0, len(prompt), 2)
        eng.add_request(rb, prompt)
        assert eng.claim_prefix(rb.rid) == 24      # capped below prompt_len
        eng.mixed_step([], rb.rid, 2)              # 2 of the 4-token suffix
        eng.abort_prefill(rb.rid)
        assert rb.recompute_tokens == 2            # not 26
        assert rb.cached_tokens == 0 and rb.prefill_tokens_done == 0
        ref = _ref_generate(model, params, prompt, 2)
        while rb.generated == 0:                   # re-claims and resumes
            eng.mixed_step([], rb.rid, 8)
        while not rb.done:
            eng.decode_step([rb.rid])
        assert eng.token_buf[rb.rid] == ref

    def test_crash_drops_tree(self, setup):
        cfg, model, params = setup
        rng = np.random.RandomState(14)
        prompt = list(rng.randint(0, cfg.vocab_size, 16))
        eng = ServingEngine(model, params, num_pages=64, page_size=8,
                            prefix_cache=True)
        r = Request(Kind.OFFLINE, 0.0, len(prompt), 2)
        eng.add_request(r, prompt)
        while r.generated == 0:
            eng.mixed_step([], r.rid, 8)
        assert eng.cache.prefix.resident_pages > 0
        eng.crash()
        assert eng.cache.prefix.resident_pages == 0

    @given(data=st.data())
    @settings(max_examples=5, deadline=None)
    def test_property_cache_on_matches_cold_reference(self, setup, data):
        """Random shared-prefix workloads through one warm engine: every
        greedy stream equals its cold whole-prompt reference."""
        cfg, model, params = setup
        vocab = cfg.vocab_size
        rng = np.random.RandomState(
            data.draw(st.integers(0, 2 ** 16), label="seed"))
        shared = list(rng.randint(0, vocab, 8 * data.draw(
            st.integers(1, 3), label="prefix_pages")))
        n_reqs = data.draw(st.integers(2, 4), label="n_reqs")
        eng = ServingEngine(model, params, num_pages=96, page_size=8,
                            prefix_cache=True)
        for _ in range(n_reqs):
            suffix = list(rng.randint(0, vocab,
                                      int(rng.randint(1, 10))))
            prompt = shared + suffix
            ref = _ref_generate(model, params, prompt, 3)
            r = Request(Kind.OFFLINE, 0.0, len(prompt), 3)
            eng.add_request(r, prompt)
            while r.generated == 0:
                eng.mixed_step([], r.rid, 8)
            while not r.done:
                eng.decode_step([r.rid])
            assert eng.token_buf[r.rid] == ref
        assert eng.stats.prefix_hits >= n_reqs - 1


# ---------------------------------------------------------------------------
# Scheduling: eviction prefers unshared pages; roofline knows about hits
# ---------------------------------------------------------------------------
def _req(prompt, generated=0):
    r = Request(Kind.OFFLINE, 0.0, prompt, 64)
    r.prefill_tokens_done = prompt
    r.generated = generated
    return r


class TestEvictionPrefersUnshared:
    def test_shared_requests_evicted_last(self):
        shared_r, private_r = _req(256), _req(256)
        shared = {shared_r.rid: 256, private_r.rid: 0}
        for bn in ("memory", "compute"):
            victims = sch.select_eviction_victims(
                [shared_r, private_r], 128, bn, shared_tokens=shared)
            assert victims == [private_r], bn

    def test_fully_shared_frees_nothing(self):
        """A request whose pages are all shared releases zero tokens —
        victim selection must keep evicting until real space is freed."""
        a, b, c = _req(128), _req(128), _req(128)
        shared = {a.rid: 128, b.rid: 0, c.rid: 0}
        victims = sch.select_eviction_victims(
            [a, b, c], 200, "memory", shared_tokens=shared)
        assert a not in victims
        assert sorted(r.rid for r in victims) == sorted([b.rid, c.rid])

    def test_partial_sharing_counts_only_releasable(self):
        a, b = _req(256), _req(160)
        shared = {a.rid: 192, b.rid: 0}            # a releases only 64
        victims = sch.select_eviction_victims(
            [a, b], 150, "compute", shared_tokens=shared)
        assert victims[0] is b                     # 160 releasable > 64

    def test_without_shared_map_behaviour_is_legacy(self):
        reqs = [_req(64), _req(256), _req(128)]
        for bn in ("memory", "compute"):
            legacy = sch.select_eviction_victims(list(reqs), 100, bn)
            with_none = sch.select_eviction_victims(
                list(reqs), 100, bn, shared_tokens=None)
            empty = sch.select_eviction_victims(
                list(reqs), 100, bn, shared_tokens={})
            assert legacy == with_none == empty


class TestCacheAwareRoofline:
    @pytest.fixture(scope="class")
    def pm(self, setup):
        return PerfModel(setup[0], TPU_V5E)

    def test_cached_tokens_cut_prefill_flops(self, pm):
        cold = pm.prefill_estimate([512])
        warm = pm.prefill_estimate([512], [384])
        assert warm.flops < cold.flops * 0.5
        assert warm.latency < cold.latency
        page_ops = [o for o in warm.ops if o.name == "page_table"]
        assert len(page_ops) == 1 and page_ops[0].flops == 0.0

    def test_hit_never_covers_whole_prompt(self, pm):
        clamped = pm.prefill_estimate([64], [64])
        assert clamped.flops == pm.prefill_estimate([64], [63]).flops
        assert clamped.flops > 0                   # >= 1 token computed

    def test_defaults_are_legacy_identical(self, pm):
        assert pm.prefill_estimate([128]).latency == \
            pm.prefill_estimate([128], [0]).latency
        assert pm.mixed_estimate(32, 96, (64, 80)).latency == \
            pm.mixed_estimate(32, 96, (64, 80), cached_tokens=0).latency

    def test_mixed_estimate_cached_context(self, pm):
        cold = pm.mixed_estimate(32, 512, (64,))
        warm = pm.mixed_estimate(32, 512, (64,), cached_tokens=448)
        assert warm.kv_bytes < cold.kv_bytes       # only the suffix is new
        assert warm.flops == cold.flops            # attention span unchanged
        # page-table bookkeeping is noise next to the dispatch overhead
        assert abs(warm.latency - cold.latency) < 1e-3 * cold.latency

    def test_gating_admits_warm_candidate_under_memory_pressure(self, pm):
        """Shared pages are already resident: only the uncached suffix
        counts against the admission memory budget."""
        cand = Request(Kind.OFFLINE, 0.0, 512, 32)
        budget = pm.kv_bytes([256])                # < full prompt, > suffix
        cold = sch.gating_decision(cand, [], pm, evict_probability=0.5,
                                   horizon_seconds=1.0,
                                   mem_budget_bytes=budget)
        warm = sch.gating_decision(cand, [], pm, evict_probability=0.5,
                                   horizon_seconds=1.0,
                                   mem_budget_bytes=budget,
                                   cached_tokens=448)
        assert not cold and warm


# ---------------------------------------------------------------------------
# Runtime: shared-prefix replay parity + counters in summary()
# ---------------------------------------------------------------------------
class TestRuntimeSharedPrefixReplay:
    @pytest.fixture(scope="class")
    def runs(self, setup):
        cfg, model, params = setup
        reqs = tr.shared_prefix_requests(
            num_prefixes=2, variants=2, queries=3, prefix_tokens=24,
            variant_tokens=8, query_tokens=8, output_len=3,
            vocab=cfg.vocab_size, seed=5)
        offline = tr.with_uniform_qps(reqs, 6.0)
        out, donor = {}, None
        for name, on in (("on", True), ("off", False)):
            from repro.cluster.runtime import (PoolRuntime, VirtualClock,
                                               replay_hw)
            rt = PoolRuntime(cfg, policy="ooco", n_strict=1, n_relaxed=1,
                             clock=VirtualClock(), backend="ref",
                             num_pages=128, page_size=8, hw=replay_hw(),
                             model=model, params=params,
                             chunk_tokens="auto", prefix_cache=on,
                             kernels_from=donor)
            donor = donor or rt.kernel_donor
            summary = rt.run([], offline, duration=12.0, max_prompt=48,
                             max_output=4, drain=True)
            out[name] = (summary, rt.finished_signature())
        return out

    def test_token_streams_bit_identical(self, runs):
        s_on, sig_on = runs["on"]
        s_off, sig_off = runs["off"]
        assert sig_on and sig_on == sig_off        # request-by-request
        assert s_on["offline_finished"] == s_off["offline_finished"] > 0

    def test_hit_counters_surface_in_summary(self, runs):
        s_on, _ = runs["on"]
        s_off, _ = runs["off"]
        assert s_on["prefix_cache"] and not s_off["prefix_cache"]
        assert s_on["prefix_hits"] > 0
        assert s_on["cached_tokens"] > 0
        assert s_on["shared_pages"] > 0
        assert s_on["prefix_evictions"] >= 0
        assert s_off["prefix_hits"] == s_off["cached_tokens"] == 0
        # same prompt tokens served, strictly less modeled prefill compute:
        # the effective-throughput ratio the prefix_reuse bench gates on
        assert s_on["prefill_tokens"] == s_off["prefill_tokens"] > 0
        assert s_on["prefill_modeled_seconds"] < \
            s_off["prefill_modeled_seconds"]
