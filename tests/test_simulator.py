"""Cluster-simulator invariants + directional policy behaviour."""
import pytest

from repro.cluster.simulator import SimConfig, Simulator
from repro.configs import get_config
from repro.core.hardware import TPU_V5E
from repro.data import traces as tr

CFG = get_config("qwen2.5-7b")


def _sim(policy, duration=60.0, seed=0):
    return Simulator(CFG, TPU_V5E, policy, SimConfig(duration=duration, tp=4,
                                                     seed=seed))


@pytest.fixture(scope="module")
def light_traces():
    online = tr.online_trace("ooc", duration=60.0, mean_qps=1.0, seed=0)
    offline = tr.with_uniform_qps(tr.offline_requests(200, seed=1), 2.0)
    return online, offline


@pytest.mark.parametrize("policy", ["base_pd", "online_priority", "ooco"])
def test_invariants(policy, light_traces):
    online, offline = light_traces
    m = _sim(policy).run(online, offline)
    assert 0.0 <= m["online_violation_rate"] <= 1.0
    assert m["offline_tokens"] >= 0
    assert m["offline_completed"] * 1 <= m["offline_tokens"] + 1
    assert m["online_requests"] == len(online)


def test_no_offline_means_zero_offline_tokens(light_traces):
    online, _ = light_traces
    m = _sim("ooco").run(online, [])
    assert m["offline_tokens"] == 0
    assert m["online_violation_rate"] <= 0.05  # light load: SLO easily met


def test_light_load_all_policies_meet_slo(light_traces):
    online, offline = light_traces
    for policy in ("base_pd", "online_priority", "ooco"):
        m = _sim(policy).run(online, offline)
        assert m["online_violation_rate"] <= 0.05, (policy, m)
        assert m["offline_tokens"] > 0


def test_heavy_offline_breaks_base_pd_not_ooco():
    """The paper's core claim, directionally: under heavy offline load,
    base P/D violates online SLOs while OOCO keeps them."""
    online = tr.online_trace("ooc", duration=90.0, mean_qps=3.0, seed=0)
    offline = tr.with_uniform_qps(tr.offline_requests(4000, seed=1), 24.0)
    base = _sim("base_pd", 90.0).run(online, offline)
    ooco = _sim("ooco", 90.0).run(online, offline)
    assert base["online_violation_rate"] > 0.03
    assert ooco["online_violation_rate"] <= 0.03
    assert ooco["offline_tokens"] > 0


def test_ooco_offline_throughput_monotone_capped():
    """More offered offline load never reduces OOCO's online compliance."""
    online = tr.online_trace("ooc", duration=60.0, mean_qps=2.0, seed=0)
    pool = tr.offline_requests(3000, seed=1)
    v_prev = None
    for qps in (2.0, 16.0):
        m = _sim("ooco").run(online, tr.with_uniform_qps(pool, qps))
        assert m["online_violation_rate"] <= 0.03
        v_prev = m


def test_migration_and_eviction_accounting():
    online = tr.online_trace("ooc", duration=90.0, mean_qps=4.0, seed=2)
    offline = tr.with_uniform_qps(tr.offline_requests(2000, seed=3), 16.0)
    sim = _sim("ooco", 90.0)
    sim.run(online, offline)
    # strict instances only ever hold decode-phase requests
    for inst in sim.strict:
        for r in inst.resident.values():
            assert r.phase.value in ("decoding",)
