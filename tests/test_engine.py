"""Serving engine: continuous batching, paged KV, layer-level interruption."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core.request import Kind, Phase, Request
from repro.engine.engine import ServingEngine
from repro.engine.kv_cache import BlockAllocator, OutOfPagesError, PagedKVCache
from repro.models.model import build_model


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2.5-7b").reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _ref_generate(model, params, prompt, n_new):
    toks = list(prompt)
    logits, cache = model.prefill(
        params, {"tokens": jnp.asarray([prompt], jnp.int32)},
        cache_len=len(prompt) + n_new)
    toks.append(int(jnp.argmax(logits, -1)[0]))
    for _ in range(n_new - 1):
        logits, cache = model.decode_step(
            params, jnp.asarray([toks[-1]], jnp.int32), cache)
        toks.append(int(jnp.argmax(logits, -1)[0]))
    return toks


class TestBlockAllocator:
    @given(ops=st.lists(st.tuples(st.booleans(), st.integers(1, 8)),
                        max_size=30))
    @settings(max_examples=40, deadline=None)
    def test_conservation(self, ops):
        a = BlockAllocator(64, reserved=1)
        held: list[list[int]] = []
        for is_alloc, n in ops:
            if is_alloc:
                try:
                    held.append(a.alloc(n))
                except OutOfPagesError:
                    pass
            elif held:
                a.free(held.pop())
        in_flight = sum(len(h) for h in held)
        assert a.free_pages + in_flight == 63  # page 0 reserved
        flat = [p for h in held for p in h]
        assert len(set(flat)) == len(flat)     # no double allocation
        assert 0 not in flat                   # trash page never handed out

    def test_out_of_pages(self):
        a = BlockAllocator(4)
        a.alloc(4)
        with pytest.raises(OutOfPagesError):
            a.alloc(1)


class TestEngine:
    def test_continuous_batching_matches_reference(self, setup):
        cfg, model, params = setup
        eng = ServingEngine(model, params, num_pages=64, page_size=8)
        rng = np.random.RandomState(0)
        prompts = [list(rng.randint(0, cfg.vocab_size, n)) for n in (13, 21, 7)]
        reqs = [Request(Kind.ONLINE, 0.0, len(p), 6) for p in prompts]
        for r, p in zip(reqs, prompts):
            eng.add_request(r, p)
            assert eng.prefill(r.rid) == "done"
        while any(not r.done for r in reqs):
            eng.decode_step([r.rid for r in reqs if not r.done])
        for r, p in zip(reqs, prompts):
            assert eng.token_buf[r.rid] == _ref_generate(model, params, p, 6)

    def test_layer_interruption_resume_identical(self, setup):
        cfg, model, params = setup
        prompt = list(np.random.RandomState(1).randint(0, cfg.vocab_size, 17))
        ref_eng = ServingEngine(model, params, num_pages=64, page_size=8)
        r0 = Request(Kind.OFFLINE, 0.0, len(prompt), 3)
        ref_eng.add_request(r0, prompt)
        ref_eng.prefill(r0.rid)
        for stop_at in range(1, cfg.num_layers):
            eng = ServingEngine(model, params, num_pages=64, page_size=8)
            r = Request(Kind.OFFLINE, 0.0, len(prompt), 3)
            eng.add_request(r, prompt)
            n = [0]
            def preempt():
                n[0] += 1
                return n[0] == stop_at
            assert eng.prefill(r.rid, should_preempt=preempt) == "preempted"
            assert r.prefill_layers_done == stop_at
            assert eng.prefill(r.rid) == "done"
            assert eng.token_buf[r.rid][-1] == ref_eng.token_buf[r0.rid][-1]
            assert eng.stats.preemptions == 1

    def test_abort_prefill_frees_pages(self, setup):
        cfg, model, params = setup
        eng = ServingEngine(model, params, num_pages=32, page_size=8)
        free0 = eng.cache.allocator.free_pages
        prompt = list(range(20))
        r = Request(Kind.OFFLINE, 0.0, 20, 3)
        eng.add_request(r, prompt)
        n = [0]
        eng.prefill(r.rid, should_preempt=lambda: True)
        eng.abort_prefill(r.rid)
        assert eng.cache.allocator.free_pages == free0
        assert r.recompute_tokens == 20
        assert r.phase == Phase.QUEUED

    def test_eviction_and_recompute(self, setup):
        cfg, model, params = setup
        eng = ServingEngine(model, params, num_pages=64, page_size=8)
        prompt = list(range(10))
        r = Request(Kind.OFFLINE, 0.0, 10, 8)
        eng.add_request(r, prompt)
        eng.prefill(r.rid)
        eng.decode_step([r.rid])
        generated = list(eng.token_buf[r.rid])
        eng.evict(r.rid)
        assert r.phase == Phase.EVICTED and r.evictions == 1
        # recompute path: re-prefill the full context (prompt + generated)
        r2 = Request(Kind.OFFLINE, 0.0, len(generated), 8 - r.generated)
        eng2 = ServingEngine(model, params, num_pages=64, page_size=8)
        eng2.add_request(r2, generated)
        assert eng2.prefill(r2.rid) == "done"

    def test_chunked_prefill_matches_reference(self, setup):
        """Landing the prompt through token-budgeted chunks (including odd,
        non-bucket sizes) must generate the same tokens as whole-prompt
        prefill + decode."""
        cfg, model, params = setup
        prompt = list(np.random.RandomState(5).randint(0, cfg.vocab_size, 21))
        ref = _ref_generate(model, params, prompt, 6)
        for chunk in (5, 8, 16, 21):
            eng = ServingEngine(model, params, num_pages=64, page_size=8)
            r = Request(Kind.OFFLINE, 0.0, len(prompt), 6)
            eng.add_request(r, prompt)
            while r.generated == 0:
                eng.mixed_step([], r.rid, chunk)
            assert r.prefill_tokens_done == len(prompt)
            assert eng.stats.prefill_chunks == -(-len(prompt) // chunk)
            while not r.done:
                eng.decode_step([r.rid])
            assert eng.token_buf[r.rid] == ref, f"chunk={chunk}"

    def test_fused_mixed_step_matches_reference(self, setup):
        """One fused dispatch = decode batch + prefill chunk: both the
        co-decoded residents and the chunked request must match their
        whole-prompt references exactly."""
        cfg, model, params = setup
        rng = np.random.RandomState(6)
        pa = list(rng.randint(0, cfg.vocab_size, 17))
        pb = list(rng.randint(0, cfg.vocab_size, 19))
        ref_a = _ref_generate(model, params, pa, 8)
        ref_b = _ref_generate(model, params, pb, 4)
        eng = ServingEngine(model, params, num_pages=64, page_size=8)
        ra = Request(Kind.OFFLINE, 0.0, len(pa), 8)
        eng.add_request(ra, pa)
        eng.prefill(ra.rid)
        rb = Request(Kind.OFFLINE, 0.0, len(pb), 4)
        eng.add_request(rb, pb)
        while rb.generated == 0:
            eng.mixed_step([ra.rid], rb.rid, 7)
        assert eng.stats.mixed_steps == 3    # ceil(19 / 7)
        while not (ra.done and rb.done):
            eng.decode_step([r.rid for r in (ra, rb) if not r.done])
        assert eng.token_buf[ra.rid] == ref_a
        assert eng.token_buf[rb.rid] == ref_b

    def test_abort_mid_chunk_prefill_no_kv_corruption(self, setup):
        """Aborting a chunk-granular prefill frees its pages and counts only
        the landed tokens as recompute waste; a resident request decoding
        across the abort (whose pages may be recycled) stays token-exact,
        and the aborted request restarts cleanly."""
        cfg, model, params = setup
        rng = np.random.RandomState(7)
        pa = list(rng.randint(0, cfg.vocab_size, 13))
        pb = list(rng.randint(0, cfg.vocab_size, 24))
        ref_a = _ref_generate(model, params, pa, 10)
        ref_b = _ref_generate(model, params, pb, 3)
        eng = ServingEngine(model, params, num_pages=32, page_size=8)
        ra = Request(Kind.OFFLINE, 0.0, len(pa), 10)
        eng.add_request(ra, pa)
        eng.prefill(ra.rid)
        free0 = eng.cache.allocator.free_pages
        rb = Request(Kind.OFFLINE, 0.0, len(pb), 3)
        eng.add_request(rb, pb)
        eng.mixed_step([ra.rid], rb.rid, 8)     # 8 of 24 tokens landed
        assert rb.prefill_tokens_done == 8
        eng.abort_prefill(rb.rid)
        assert eng.cache.allocator.free_pages == free0
        assert rb.recompute_tokens == 8         # only the landed chunk
        assert rb.phase == Phase.QUEUED and rb.prefill_tokens_done == 0
        # resume from scratch (fresh pages, possibly the recycled ones)
        while rb.generated == 0:
            eng.mixed_step([ra.rid], rb.rid, 8)
        while not (ra.done and rb.done):
            eng.decode_step([r.rid for r in (ra, rb) if not r.done])
        assert eng.token_buf[ra.rid] == ref_a   # co-decoded, never corrupted
        assert eng.token_buf[rb.rid] == ref_b

    def test_prefill_trace_count_stable(self, setup):
        """Length bucketing: arbitrary prompt lengths must reuse a small set
        of jit traces (one per bucket), not retrace per unique length —
        for the whole-prompt path AND the chunked/fused path."""
        cfg, model, params = setup
        eng = ServingEngine(model, params, num_pages=256, page_size=8,
                            decode_buckets=(4,))
        rng = np.random.RandomState(8)
        lengths = list(range(9, 33))            # 24 distinct lengths
        for n in lengths:
            p = list(rng.randint(0, cfg.vocab_size, n))
            r = Request(Kind.OFFLINE, 0.0, n, 1)
            eng.add_request(r, p)
            eng.prefill(r.rid)
        buckets = {ServingEngine.pad_chunk(n) for n in lengths}
        assert eng._layer_fn._cache_size() <= len(buckets)  # {16, 32} -> 2
        # chunked path: odd chunk lengths share bucketed mixed-fn traces
        mixed_before = len(eng._mixed_fns)
        for i, chunk in enumerate((5, 6, 7, 8)):
            p = list(rng.randint(0, cfg.vocab_size, 8))
            r = Request(Kind.OFFLINE, 0.0, 8, 1)
            eng.add_request(r, p)
            eng.mixed_step([], r.rid, chunk)
        assert len(eng._mixed_fns) == mixed_before + 1  # one (8-token) trace

    def test_chunked_pages_allocated_incrementally(self, setup):
        """Chunk-granular prefill claims pages as chunks land, so a paused
        prefill only holds capacity for its landed prefix."""
        cfg, model, params = setup
        eng = ServingEngine(model, params, num_pages=64, page_size=8)
        free0 = eng.cache.allocator.free_pages
        r = Request(Kind.OFFLINE, 0.0, 40, 2)
        eng.add_request(r, list(range(40)))
        eng.mixed_step([], r.rid, 8)
        assert eng.cache.allocator.free_pages == free0 - 1   # 8 of 40 tokens
        eng.mixed_step([], r.rid, 8)
        assert eng.cache.allocator.free_pages == free0 - 2

    def test_migration_roundtrip(self, setup):
        """migrate_out -> migrate_in preserves generation exactly."""
        cfg, model, params = setup
        src = ServingEngine(model, params, num_pages=64, page_size=8)
        dst = ServingEngine(model, params, num_pages=64, page_size=8)
        prompt = list(np.random.RandomState(3).randint(0, cfg.vocab_size, 12))
        ref = _ref_generate(model, params, prompt, 6)
        r = Request(Kind.OFFLINE, 0.0, len(prompt), 6)
        src.add_request(r, prompt)
        src.prefill(r.rid)
        src.decode_step([r.rid])  # 2 tokens generated now
        k, v, n = src.migrate_out(r.rid)
        dst.migrate_in(r.rid, r, src.token_buf[r.rid], k, v, n)
        while not r.done:
            dst.decode_step([r.rid])
        assert dst.token_buf[r.rid] == ref
