"""Pool-runtime co-location suite (ISSUE 3).

* virtual-clock trace replay is bit-deterministic: same seed → identical
  finished-request set, token streams, and metric values across runs;
* policy SLO discrimination on a bursty synthetic trace: ``ooco`` meets the
  TPOT SLO while ``base_pd`` does not, and ``ooco`` beats
  ``online_priority`` on offline tokens/s at equal-or-better attainment;
* arbitrary N-strict + M-relaxed topologies drain their traces;
* property tests (hypothesis, skip-safe per tests/conftest.py) for the
  scheduling points the runtime routes through: eviction victims always
  free enough and never include online work, mix-decoding batches never
  exceed the SLO bound under the perf model.
"""
import jax
import pytest
from hypothesis import given, settings, strategies as st

from repro.cluster.runtime import PoolRuntime, VirtualClock, replay_hw
from repro.configs import get_config
from repro.core import scheduling as sch
from repro.core.perf_model import PerfModel
from repro.core.request import Kind, Request
from repro.data import traces as tr
from repro.models.model import build_model

SLO_TTFT = 1.0
SLO_TPOT = 0.030


@pytest.fixture(scope="module")
def built():
    cfg = get_config("qwen2.5-7b").reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params, [None]   # last slot: shared kernel donor


def _replay(built, policy, *, seed=0, n_strict=1, n_relaxed=2,
            n_offline=100, offline_qps=20.0, online_qps=1.2, duration=10.0,
            max_output=12, drain=False, chunk_tokens="auto"):
    """Deterministic virtual-clock replay of a bursty synthetic trace.

    Defaults use a fixed evaluation window under a saturating offline
    backlog (the §5.2 protocol): every policy gets the same window, so
    offline tokens/s measures what the policy extracted at its SLO
    attainment. Chunked prefill is on by default (the production path);
    ``chunk_tokens=0`` replays through the legacy whole-prompt prefill."""
    cfg, model, params, donor = built
    rt = PoolRuntime(cfg, policy=policy, n_strict=n_strict,
                     n_relaxed=n_relaxed, clock=VirtualClock(), backend="ref",
                     num_pages=256, page_size=8, slo_ttft=SLO_TTFT,
                     slo_tpot=SLO_TPOT, hw=replay_hw(), seed=seed,
                     model=model, params=params, chunk_tokens=chunk_tokens,
                     kernels_from=donor[0])
    donor[0] = donor[0] or rt.kernel_donor
    online = tr.online_trace("ooc", duration=duration, mean_qps=online_qps,
                             seed=seed)
    offline = tr.with_uniform_qps(
        tr.offline_requests(n_offline, seed=seed + 1), offline_qps)
    summary = rt.run(online, offline, duration=duration, max_prompt=48,
                     max_output=max_output, drain=drain)
    return summary, rt


@pytest.fixture(scope="module")
def policy_runs(built):
    return {p: _replay(built, p)
            for p in ("ooco", "base_pd", "online_priority")}


class TestVirtualClockDeterminism:
    def test_replay_is_bit_deterministic(self, built, policy_runs):
        m1, rt1 = policy_runs["ooco"]
        m2, rt2 = _replay(built, "ooco")   # fresh runtime, fresh engines
        assert m1 == m2                    # every metric value identical
        assert rt1.finished_signature() == rt2.finished_signature()
        # the signature covers the finished set AND full token streams
        assert len(rt1.finished_signature()) == len(rt1.finished)
        assert rt1.finished

    def test_replay_work_actually_happened(self, policy_runs):
        m, rt = policy_runs["ooco"]
        assert m["online_finished"] == m["online_requests"] > 0
        assert m["offline_finished"] > 0
        assert m["offline_tokens"] > 0
        assert all(len(toks) > 0 for toks in rt.tokens.values())


class TestPolicyDiscrimination:
    def test_ooco_meets_tpot_slo_base_pd_does_not(self, policy_runs):
        ooco, _ = policy_runs["ooco"]
        base, _ = policy_runs["base_pd"]
        assert ooco["online_tpot_p99"] <= SLO_TPOT * (1 + 1e-9)
        assert base["online_tpot_p99"] > SLO_TPOT
        assert ooco["online_slo_attainment"] > base["online_slo_attainment"]

    def test_ooco_beats_online_priority_offline_throughput(self, policy_runs):
        ooco, _ = policy_runs["ooco"]
        op, _ = policy_runs["online_priority"]
        assert ooco["online_slo_attainment"] >= op["online_slo_attainment"]
        assert ooco["offline_tokens_per_s"] > op["offline_tokens_per_s"]

    def test_ooco_exercises_cluster_mechanisms(self, policy_runs):
        """The §3.4 machinery must actually fire on the real path."""
        m, _ = policy_runs["ooco"]
        assert m["migrations"] > 0          # real relaxed→strict KV movement
        assert m["pulls"] > 0               # §3.4.3 pull-model migration

    def test_baselines_do_not_pull_or_preempt(self, policy_runs):
        for p in ("base_pd", "online_priority"):
            m, _ = policy_runs[p]
            assert m["pulls"] == 0
            assert m["preemptions"] == 0

    def test_virtual_clock_layer_preemption_fires(self, built):
        """§3.4.1 under the virtual clock: an online arrival landing inside
        an offline prefill window interrupts it at a layer boundary —
        deterministically, with no wall-clock involvement."""
        cfg, model, params, donor = built
        rt = PoolRuntime(cfg, policy="ooco", n_strict=1, n_relaxed=1,
                         clock=VirtualClock(), backend="ref", num_pages=128,
                         page_size=8, slo_ttft=SLO_TTFT, slo_tpot=SLO_TPOT,
                         hw=replay_hw(), seed=0, model=model, params=params,
                         kernels_from=donor[0])
        offline = [tr.TraceRequest(0.0, 48, 4)]
        online = [tr.TraceRequest(0.005, 16, 4)]   # mid-prefill arrival
        m = rt.run(online, offline, duration=2.0, max_prompt=48, max_output=4)
        assert m["preemptions"] >= 1
        assert m["online_finished"] == 1 and m["offline_finished"] == 1


class TestChunkedPrefill:
    """Chunked prefill + fused mixed steps through the pool runtime:
    §3.4.1 preemption at deterministic chunk boundaries under the virtual
    clock, bit-identical replay with chunking on, and the TTFT payoff vs
    whole-prompt prefill on a bursty trace."""

    def test_chunked_replay_bit_deterministic(self, built):
        m1, rt1 = _replay(built, "ooco", chunk_tokens=8)
        m2, rt2 = _replay(built, "ooco", chunk_tokens=8)
        assert m1 == m2
        assert rt1.finished_signature() == rt2.finished_signature()
        assert m1["chunks"] > 0                 # the fused path actually ran

    def test_chunk_boundary_preemption_fires(self, built):
        """An online arrival landing inside a long offline prefill pauses it
        at the next chunk boundary — deterministically, with the offline
        request keeping its landed prefix (no layer re-execution: zero
        recompute tokens)."""
        cfg, model, params, donor = built
        rt = PoolRuntime(cfg, policy="ooco", n_strict=1, n_relaxed=1,
                         clock=VirtualClock(), backend="ref", num_pages=128,
                         page_size=8, slo_ttft=SLO_TTFT, slo_tpot=SLO_TPOT,
                         hw=replay_hw(), seed=0, model=model, params=params,
                         chunk_tokens=8, kernels_from=donor[0])
        offline = [tr.TraceRequest(0.0, 48, 4)]
        online = [tr.TraceRequest(0.005, 16, 4)]   # mid-prefill arrival
        m = rt.run(online, offline, duration=2.0, max_prompt=48, max_output=4)
        assert m["chunk_preemptions"] >= 1
        assert m["preemptions"] >= 1               # unified §3.4.1 counter
        assert m["online_finished"] == 1 and m["offline_finished"] == 1
        assert m["recompute_tokens"] == 0          # paused, never re-run

    def test_chunked_ttft_beats_whole_prompt_prefill(self, built):
        """On the bursty co-location trace, chunk-boundary preemption must
        tighten online TTFT vs the legacy whole-prompt path at no offline
        throughput cost (the ISSUE's headline tradeoff)."""
        chunked, _ = _replay(built, "ooco", chunk_tokens="auto")
        legacy, _ = _replay(built, "ooco", chunk_tokens=0)
        assert chunked["online_ttft_p99"] < legacy["online_ttft_p99"]
        assert chunked["online_ttft_p50"] < legacy["online_ttft_p50"]
        assert (chunked["offline_tokens_per_s"]
                >= legacy["offline_tokens_per_s"] * (1 - 1e-9))
        assert chunked["online_slo_attainment"] >= legacy["online_slo_attainment"]

    def test_fixed_budget_cli_value_drains(self, built):
        """A fixed --chunk-tokens N budget (not auto) still drains a mixed
        trace with every request finished."""
        m, rt = _replay(built, "ooco", chunk_tokens=16, n_offline=16,
                        offline_qps=50.0, duration=6.0, drain=True)
        assert m["offline_finished"] == m["offline_requests"]
        assert m["online_finished"] == m["online_requests"]
        assert m["chunks"] > 0


class TestTopology:
    def test_multi_strict_multi_relaxed_drains(self, built):
        # an offline burst at t=0 plus steady online traffic spreads work
        # over every engine of a 2-strict + 2-relaxed topology
        m, rt = _replay(built, "ooco", n_strict=2, n_relaxed=2,
                        n_offline=16, offline_qps=50.0, online_qps=2.0,
                        duration=6.0, max_output=8)
        assert m["online_finished"] == m["online_requests"] > 0
        assert m["offline_finished"] == m["offline_requests"]
        assert m["migrations"] > 0
        assert all(s.engine.stats.decode_steps > 0 for s in rt.strict_pool)
        assert all(s.engine.stats.prefill_tokens > 0 for s in rt.relaxed_pool)


# ---------------------------------------------------------------------------
# property tests for the scheduling points the runtime routes through
# ---------------------------------------------------------------------------

_PM = PerfModel(get_config("qwen2.5-7b").reduced(), replay_hw())


def _reqs(kind, lens):
    return [Request(kind, 0.0, int(max(l, 1)), 8) for l in lens]


class TestSchedulingProperties:
    @given(off=st.lists(st.integers(1, 4096), min_size=0, max_size=24),
           on=st.lists(st.integers(1, 4096), min_size=0, max_size=8),
           need=st.integers(1, 60000),
           bn=st.sampled_from(["compute", "memory", "balanced"]))
    @settings(max_examples=60, deadline=None)
    def test_eviction_frees_enough_and_never_online(self, off, on, need, bn):
        """Victims free >= the requested tokens (or are ALL offline work),
        and never include an online request even on a mixed resident list."""
        mixed = _reqs(Kind.OFFLINE, off) + _reqs(Kind.ONLINE, on)
        victims = sch.select_eviction_victims(mixed, need, bn)
        assert all(v.kind is Kind.OFFLINE for v in victims)
        freed = sum(v.context_len for v in victims)
        n_offline = sum(1 for r in mixed if r.kind is Kind.OFFLINE)
        assert freed >= need or len(victims) == n_offline
        ids = [v.rid for v in victims]
        assert len(set(ids)) == len(ids)

    @given(on=st.lists(st.integers(1, 2048), min_size=0, max_size=6),
           off=st.lists(st.integers(1, 2048), min_size=0, max_size=24),
           seed=st.integers(0, 7))
    @settings(max_examples=60, deadline=None)
    def test_mix_decoding_respects_slo_bound(self, on, off, seed):
        """All online requests always ride; any admitted offline keeps the
        perf-model-predicted step latency within the TPOT SLO."""
        import random
        online = _reqs(Kind.ONLINE, on)
        offline = _reqs(Kind.OFFLINE, off)
        batch = sch.mix_decoding_selection(online, offline, SLO_TPOT, _PM,
                                           rng=random.Random(seed))
        assert batch[: len(online)] == online
        ids = [r.rid for r in batch]
        assert len(set(ids)) == len(ids)
        if len(batch) > len(online):
            lat = _PM.decode_estimate([r.context_len for r in batch]).latency
            assert lat <= SLO_TPOT * (1 + 1e-9)
