"""Unit tests: mamba2 chunked-vs-sequential oracle, MoE dispatch vs dense
reference, HLO analysis trip counting, flash-xla vs naive, training loop."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import mamba2, moe as moe_lib
from repro.models.attention import flash_attention_xla, naive_attention_xla


class TestMamba2:
    @pytest.mark.parametrize("S", [16, 48, 37])  # incl. non-chunk-multiple
    def test_chunked_matches_sequential(self, S, rng):
        cfg = get_config("zamba2-7b").reduced()
        p = mamba2.init_mamba(rng, cfg)
        u = jax.random.normal(jax.random.fold_in(rng, 1), (2, S, cfg.d_model),
                              jnp.bfloat16)
        y1, (c1, s1) = mamba2.mamba_prefill(p, u, cfg)
        y2, (c2, s2) = mamba2.mamba_ref_scan(p, u, cfg)
        np.testing.assert_allclose(np.asarray(y1, np.float32),
                                   np.asarray(y2, np.float32), atol=3e-2)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-3)
        np.testing.assert_allclose(np.asarray(c1, np.float32),
                                   np.asarray(c2, np.float32), atol=1e-3)

    def test_padding_is_state_transparent(self, rng):
        """Trailing padding (dt=0) must not change the carried state."""
        cfg = get_config("zamba2-7b").reduced()
        p = mamba2.init_mamba(rng, cfg)
        u = jax.random.normal(jax.random.fold_in(rng, 2), (1, 19, cfg.d_model),
                              jnp.bfloat16)
        _, (c1, s1) = mamba2.mamba_prefill(p, u, cfg)       # pads 19 -> 32
        _, (c2, s2) = mamba2.mamba_ref_scan(p, u, cfg)      # no padding
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-3)


class TestRWKV6:
    @pytest.mark.parametrize("S", [8, 32, 45])  # incl. non-chunk-multiple
    def test_chunked_wkv_matches_scan(self, S, rng):
        from repro.models import rwkv6
        B, H, K = 2, 3, 16
        ks = jax.random.split(rng, 5)
        r = jax.random.normal(ks[0], (B, S, H, K))
        k = jax.random.normal(ks[1], (B, S, H, K))
        v = jax.random.normal(ks[2], (B, S, H, K))
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, K))) * 0.5 + 0.45
        u = jax.random.normal(ks[4], (H, K)) * 0.1
        s0 = jax.random.normal(jax.random.fold_in(rng, 9), (B, H, K, K)) * 0.1
        y1, st1 = rwkv6._wkv_scan(r, k, v, w, u, s0)
        y2, st2 = rwkv6._wkv_chunked(r, k, v, w, u, s0, chunk=16)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(st1), np.asarray(st2),
                                   atol=1e-4, rtol=1e-4)


class TestMoE:
    @given(B=st.integers(1, 4), S=st.sampled_from([4, 8, 16]),
           groups=st.sampled_from([1, 2, 4]))
    @settings(max_examples=15, deadline=None)
    def test_capacity_dispatch_matches_dense(self, B, S, groups):
        cfg = dataclasses.replace(get_config("mixtral-8x22b").reduced(),
                                  moe_capacity_factor=8.0)  # no drops
        p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model),
                              jnp.float32)
        out, aux = moe_lib.moe_mlp(p, x, cfg, groups=groups)
        ref = moe_lib.moe_ref(p, x, cfg)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-3, rtol=2e-2)
        assert np.isfinite(float(aux))

    def test_capacity_drops_dont_nan(self):
        cfg = dataclasses.replace(get_config("granite-moe-3b-a800m").reduced(),
                                  moe_capacity_factor=0.5)  # force drops
        p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                              jnp.bfloat16)
        out, _ = moe_lib.moe_mlp(p, x, cfg, groups=2)
        assert not np.any(np.isnan(np.asarray(out, np.float32)))


class TestFlashXLA:
    @given(Sq=st.sampled_from([64, 100]), window=st.sampled_from([0, 32]),
           cap=st.sampled_from([0.0, 30.0]))
    @settings(max_examples=12, deadline=None)
    def test_flash_matches_naive(self, Sq, window, cap):
        rng = jax.random.PRNGKey(0)
        q = jax.random.normal(rng, (2, Sq, 4, 32))
        k = jax.random.normal(jax.random.fold_in(rng, 1), (2, Sq, 2, 32))
        v = jax.random.normal(jax.random.fold_in(rng, 2), (2, Sq, 2, 32))
        a = flash_attention_xla(q, k, v, window=window, logit_softcap=cap,
                                kv_block=32)
        b = naive_attention_xla(q, k, v, window=window, logit_softcap=cap)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


class TestHloAnalysis:
    def test_scan_trip_count_correction(self):
        from repro.launch.hlo_analysis import analyze

        def f(x, ws):
            def body(c, w):
                return jnp.dot(c, w), None
            return jax.lax.scan(body, x, ws)[0]

        x = jax.ShapeDtypeStruct((64, 128), jnp.bfloat16)
        ws = jax.ShapeDtypeStruct((5, 128, 128), jnp.bfloat16)
        comp = jax.jit(f).lower(x, ws).compile()
        res = analyze(comp.as_text())
        assert res["dot_flops_per_device"] == pytest.approx(
            5 * 2 * 64 * 128 * 128, rel=1e-6)

    def test_nested_scan(self):
        from repro.launch.hlo_analysis import analyze

        def f(x, ws):
            def outer(c, w):
                def inner(c2, _):
                    return jnp.dot(c2, w), None
                return jax.lax.scan(inner, c, None, length=3)[0], None
            return jax.lax.scan(outer, x, ws)[0]

        x = jax.ShapeDtypeStruct((32, 64), jnp.bfloat16)
        ws = jax.ShapeDtypeStruct((4, 64, 64), jnp.bfloat16)
        comp = jax.jit(f).lower(x, ws).compile()
        res = analyze(comp.as_text())
        assert res["dot_flops_per_device"] == pytest.approx(
            4 * 3 * 2 * 32 * 64 * 64, rel=1e-6)


class TestTraining:
    def test_loss_decreases_and_checkpoint_roundtrips(self, rng, tmp_path):
        from repro.training import checkpoint as ckpt
        from repro.training.data_pipeline import DataConfig, packed_batches
        from repro.training.optimizer import AdamWConfig
        from repro.training.train_loop import train
        from repro.models.model import build_model

        cfg = get_config("tinyllama-1.1b").reduced()
        model = build_model(cfg, remat=True)
        params = model.init(rng)
        dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, batch_size=4)
        params2, opt, hist = train(
            model, params, packed_batches(dc, 25),
            AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=25), log_every=24)
        assert hist[-1][1] < hist[0][1]
        path = str(tmp_path / "ck.npz")
        ckpt.save(path, params2, opt, step=25)
        rp, ro, step = ckpt.restore(path, params2, opt)
        assert step == 25
        for a, b in zip(jax.tree.leaves(params2), jax.tree.leaves(rp)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    def test_grad_accum_matches_single_batch(self, rng):
        from repro.training.optimizer import AdamWConfig, init_opt_state
        from repro.training.train_loop import make_train_step
        from repro.models.model import build_model

        cfg = get_config("tinyllama-1.1b").reduced()
        model = build_model(cfg, remat=False)
        params = model.init(rng)
        tokens = jax.random.randint(rng, (4, 32), 0, cfg.vocab_size)
        batch = {"tokens": tokens, "labels": tokens}
        oc = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
        s1 = jax.jit(make_train_step(model, oc, microbatches=1))
        s2 = jax.jit(make_train_step(model, oc, microbatches=2))
        p1, _, m1 = s1(params, init_opt_state(params), batch)
        p2, _, m2 = s2(params, init_opt_state(params), batch)
        assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=2e-2)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), atol=2e-2)

    def test_lr_schedule(self):
        from repro.training.optimizer import AdamWConfig, lr_at
        oc = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                         min_lr_ratio=0.1)
        assert float(lr_at(oc, 0)) == 0.0
        assert float(lr_at(oc, 10)) == pytest.approx(1e-3)
        assert float(lr_at(oc, 100)) == pytest.approx(1e-4, rel=1e-2)
