"""Trace synthesis (Table 5 statistics) and §5.1.3 scaling invariants."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import traces as tr


@pytest.mark.parametrize("ds,key", [("ooc", "ooc_online"),
                                    ("azure_conv", "azure_conv"),
                                    ("azure_code", "azure_code")])
def test_table5_length_statistics(ds, key):
    t = tr.online_trace(ds, duration=1200, mean_qps=4.0, seed=0)
    s = tr.trace_stats(t)
    want_p, want_o = tr.DATASET_STATS[key]
    assert s["avg_prompt"] == pytest.approx(want_p, rel=0.15)
    assert s["avg_output"] == pytest.approx(want_o, rel=0.20)
    assert s["mean_qps"] == pytest.approx(4.0, rel=0.25)


def test_burstiness_present():
    t = tr.online_trace("ooc", duration=1200, mean_qps=4.0, seed=0)
    s = tr.trace_stats(t)
    assert s["peak_over_mean"] > 1.5  # Fig. 1: bursty spikes exist


def test_arrivals_sorted_and_within_duration():
    t = tr.online_trace("ooc", duration=300, mean_qps=2.0, seed=1)
    ts = [r.arrival for r in t]
    assert ts == sorted(ts)
    assert 0 <= ts[0] and ts[-1] <= 300.0


@given(factor=st.sampled_from([0.25, 0.5, 2.0, 3.0]))
@settings(max_examples=8, deadline=None)
def test_scaling_changes_rate_preserves_pattern(factor):
    base = tr.online_trace("ooc", duration=900, mean_qps=4.0, seed=0)
    scaled = tr.scale_trace(base, factor, seed=0)
    s0, s1 = tr.trace_stats(base), tr.trace_stats(scaled)
    assert s1["mean_qps"] / s0["mean_qps"] == pytest.approx(factor, rel=0.15)
    # temporal pattern (burst ratio) preserved within tolerance
    assert s1["peak_over_mean"] / s0["peak_over_mean"] == pytest.approx(1.0, rel=0.35)
    # lengths distribution preserved
    assert s1["avg_prompt"] == pytest.approx(s0["avg_prompt"], rel=0.15)


def test_uniform_qps_spacing():
    reqs = tr.offline_requests(100, seed=0)
    placed = tr.with_uniform_qps(reqs, 4.0)
    gaps = np.diff([r.arrival for r in placed])
    assert np.allclose(gaps, 0.25)


def test_scale_one_is_identity():
    base = tr.online_trace("ooc", duration=100, mean_qps=1.0, seed=0)
    assert tr.scale_trace(base, 1.0) == base
